"""FlashMLA in the tile DSL — the paper's Fig. 18 composed from the shared
attention core, plus its serving variants.

Multi-head Latent Attention (DeepSeek-V2): all query heads of a group attend
to one shared latent KV (dim) plus a rotary part (pe_dim); V is the latent
itself.  The paper reports this kernel at 98% of hand-optimized FlashMLA in
~70 lines — the headline usability result we reproduce here.

Three programs share the template (attention_core.py), differing only in
composition points:

* :func:`mla_program` — contiguous KV window, block-max softmax (the
  paper's formulation), no mask: the Fig. 18 port.
* :func:`mla_paged_program` — the **paged MLA decode** kernel: latent and
  rope pages gathered through a block table (the same scalar-prefetch path
  as paged_attention.py), grid over slots, ragged live-length mask.  This
  is what admits MLA models to the vLLM-style serving cache.
* :func:`mla_prefill_program` — **MLA chunked prefill**: a (slots, chunk)
  block of prompt latents attends prior latent pages plus itself causally
  and writes its own latent/rope pages from inside the kernel
  (table-directed output BlockSpecs, as in prefill_attention.py).
"""

import math
from typing import Optional

from repro.core import TileProgram
from repro.core import lang as T

from . import attention_core as AC


def mla_program(
    batch: int,
    heads: int,
    kv_head_num: int,
    seqlen_kv: int,
    dim: int,
    pe_dim: int,
    block_N: int = 128,
    block_H: int = 64,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
    swizzle: Optional[int] = None,
) -> TileProgram:
    if seqlen_kv % block_N:
        raise ValueError("seqlen_kv must divide block_N")
    kv_group_num = heads // kv_head_num
    VALID_BLOCK_H = min(block_H, kv_group_num)
    if heads % VALID_BLOCK_H:
        raise ValueError("the valid head block must divide heads")
    scale = (
        sm_scale if sm_scale is not None else 1.0 / math.sqrt(dim + pe_dim)
    ) * 1.44269504  # log2(e)

    @T.prim_func
    def FlashMLA(
        Q: T.Tensor((batch, heads, dim), dtype),
        Q_pe: T.Tensor((batch, heads, pe_dim), dtype),
        KV: T.Tensor((batch, seqlen_kv, kv_head_num, dim), dtype),
        K_pe: T.Tensor((batch, seqlen_kv, kv_head_num, pe_dim), dtype),
        Output: T.Tensor((batch, heads, dim), dtype),
    ):
        with T.Kernel(batch, heads // VALID_BLOCK_H, threads=256) as (bx, by):
            Q_shared = T.alloc_shared((VALID_BLOCK_H, dim), dtype)
            S_shared = T.alloc_shared((VALID_BLOCK_H, block_N), dtype)
            Q_pe_shared = T.alloc_shared((VALID_BLOCK_H, pe_dim), dtype)
            KV_shared = T.alloc_shared((block_N, dim), dtype)
            K_pe_shared = T.alloc_shared((block_N, pe_dim), dtype)
            acc_s = T.alloc_fragment((VALID_BLOCK_H, block_N), accum_dtype)
            # the paper's Fig. 18 formulation: per-block max (not running),
            # probabilities staged through shared memory for the P·V GEMM
            ons = AC.OnlineSoftmax(VALID_BLOCK_H, dim, scale, accum_dtype,
                                   running_max=False, clamp_current=False,
                                   shared_scores=S_shared)

            cur_kv_head = by // (kv_group_num // VALID_BLOCK_H)
            if swizzle:
                T.use_swizzle(swizzle)

            T.copy(Q[bx, by * VALID_BLOCK_H : (by + 1) * VALID_BLOCK_H, :], Q_shared)
            T.copy(
                Q_pe[bx, by * VALID_BLOCK_H : (by + 1) * VALID_BLOCK_H, :], Q_pe_shared
            )

            def load_kv(k):
                T.copy(
                    KV[bx, k * block_N : (k + 1) * block_N, cur_kv_head, :], KV_shared
                )
                T.copy(
                    K_pe[bx, k * block_N : (k + 1) * block_N, cur_kv_head, :],
                    K_pe_shared,
                )
                return KV_shared, KV_shared  # V is the latent itself

            AC.attend(
                ons, acc_s, block_N, T.ceildiv(seqlen_kv, block_N), load_kv,
                lambda s, ks, k: AC.scores(
                    s, Q_shared, ks, extra=[(Q_pe_shared, K_pe_shared)]
                ),
                num_stages=num_stages,
            )
            ons.finalize(Output[bx, by * VALID_BLOCK_H : (by + 1) * VALID_BLOCK_H, :])

    return FlashMLA


def mla_paged_program(
    slots: int,
    heads: int,
    dim: int,
    pe_dim: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    block_H: int = 64,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
) -> TileProgram:
    """Paged MLA decode: one latent query row block per slot, latent+rope
    pages gathered through the block table (scalar prefetch), ragged mask
    against each slot's live length (optionally sliding-window limited).
    The latent is shared by every query head, so there is no kv-head grid
    axis — the pool is ``(num_pages, page_size, dim)``."""
    bh = min(block_H, heads)
    if heads % bh:
        raise ValueError("the head block must divide heads")
    scale = (
        sm_scale if sm_scale is not None else 1.0 / math.sqrt(dim + pe_dim)
    ) * 1.44269504  # log2(e)

    @T.prim_func
    def PagedMLA(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Lens: T.ScalarTensor((slots,), "int32"),
        Q: T.Tensor((slots, heads, dim), dtype),
        Q_pe: T.Tensor((slots, heads, pe_dim), dtype),
        KVPages: T.Tensor((num_pages, page_size, dim), dtype),
        KPePages: T.Tensor((num_pages, page_size, pe_dim), dtype),
        Output: T.Tensor((slots, heads, dim), dtype),
    ):
        with T.Kernel(heads // bh, slots) as (by, bz):
            Q_shared = T.alloc_shared((bh, dim), dtype)
            Q_pe_shared = T.alloc_shared((bh, pe_dim), dtype)
            KV_shared = T.alloc_shared((page_size, dim), dtype)
            K_pe_shared = T.alloc_shared((page_size, pe_dim), dtype)
            acc_s = T.alloc_fragment((bh, page_size), accum_dtype)
            # safe_div: empty slots (len 0) divide by the floor -> zeros
            ons = AC.OnlineSoftmax(bh, dim, scale, accum_dtype, safe_div=True)

            T.copy(Q[bz, by * bh, 0], Q_shared)
            T.copy(Q_pe[bz, by * bh, 0], Q_pe_shared)

            def load_kv(k):
                # the paged gather: page index loaded from the block table
                T.copy(KVPages[Tables[bz, k], 0, 0], KV_shared)
                T.copy(KPePages[Tables[bz, k], 0, 0], K_pe_shared)
                return KV_shared, KV_shared  # V is the latent itself

            def mask(k):
                return AC.ragged(Lens[bz], lambda j: k * page_size + j, window)

            AC.attend(
                ons, acc_s, page_size, max_pages, load_kv,
                lambda s, ks, k: AC.scores(
                    s, Q_shared, ks, extra=[(Q_pe_shared, K_pe_shared)]
                ),
                mask, num_stages=num_stages,
            )
            ons.finalize(Output[bz, by * bh, 0])

    return PagedMLA


def mla_prefill_program(
    slots: int,
    heads: int,
    dim: int,
    pe_dim: int,
    chunk: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
) -> TileProgram:
    """MLA chunked prefill: a (slots, chunk) block of prompt latents attends
    prior latent pages (gathered through the block table) plus itself
    causally, and writes its own latent/rope pages from inside the kernel.

    Queries are packed chunk-major with their head — row ``i * heads + h``
    is chunk position ``i`` of head ``h`` — so each grid cell attends a
    ``(page_size * heads, dim)`` query tile (the prefill_attention packing
    with the whole head count as the group).  Same contract as
    prefill_attention.py: ``chunk % page_size == 0``, live ``Starts``
    page-aligned, dead chunk pages land in the reserved garbage page 0.
    """
    if chunk % page_size:
        raise ValueError("chunk must be a multiple of page_size")
    cpp = chunk // page_size  # chunk pages written per slot
    rows = page_size * heads  # query rows per grid cell (chunk-major packed)
    scale = (
        sm_scale if sm_scale is not None else 1.0 / math.sqrt(dim + pe_dim)
    ) * 1.44269504  # log2(e)

    @T.prim_func
    def PrefillMLA(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Starts: T.ScalarTensor((slots,), "int32"),  # prior tokens (page-aligned)
        Lens: T.ScalarTensor((slots,), "int32"),  # live tokens in the chunk
        Q: T.Tensor((slots, chunk * heads, dim), dtype),
        Q_pe: T.Tensor((slots, chunk * heads, pe_dim), dtype),
        CKV: T.Tensor((slots, chunk, dim), dtype),  # the chunk's own latents
        KPE: T.Tensor((slots, chunk, pe_dim), dtype),
        KVPages: T.Tensor((num_pages, page_size, dim), dtype),
        KPePages: T.Tensor((num_pages, page_size, pe_dim), dtype),
        Output: T.Tensor((slots, chunk * heads, dim), dtype),
    ):
        with T.Kernel(cpp, slots) as (bq, bz):
            Q_shared = T.alloc_shared((rows, dim), dtype)
            Q_pe_shared = T.alloc_shared((rows, pe_dim), dtype)
            Kc_shared = T.alloc_shared((chunk, dim), dtype)
            Pc_shared = T.alloc_shared((chunk, pe_dim), dtype)
            Kp_shared = T.alloc_shared((page_size, dim), dtype)
            Pp_shared = T.alloc_shared((page_size, pe_dim), dtype)
            acc_s = T.alloc_fragment((rows, page_size), accum_dtype)
            acc_c = T.alloc_fragment((rows, chunk), accum_dtype)
            # safe_div: rows past Lens are fully masked -> zeros, not nan
            ons = AC.OnlineSoftmax(rows, dim, scale, accum_dtype,
                                   safe_div=True)

            T.copy(Q[bz, bq * rows, 0], Q_shared)
            T.copy(Q_pe[bz, bq * rows, 0], Q_pe_shared)
            T.copy(CKV[bz, 0, 0], Kc_shared)
            T.copy(KPE[bz, 0, 0], Pc_shared)

            # ---- prior latents, gathered through the block table ---------
            def load_prior(kp):
                T.copy(KVPages[Tables[bz, kp], 0, 0], Kp_shared)
                T.copy(KPePages[Tables[bz, kp], 0, 0], Pp_shared)
                return Kp_shared, Kp_shared  # V is the latent itself

            q_pos = lambda r: Starts[bz] + bq * page_size + r // heads

            def prior_mask(kp):
                k_pos = lambda j: kp * page_size + j
                m = AC.ragged(Starts[bz], k_pos)
                if window is not None:
                    m = AC.both(m, AC.banded(q_pos, k_pos, window))
                return m

            AC.attend(
                ons, acc_s, page_size, max_pages, load_prior,
                lambda s, ks, kp: AC.scores(
                    s, Q_shared, ks, extra=[(Q_pe_shared, Pp_shared)]
                ),
                prior_mask, num_stages=num_stages,
            )

            # ---- the chunk itself (latents straight from the CKV/KPE
            # inputs — never read back through the pages we are writing) ---
            AC.scores(acc_c, Q_shared, Kc_shared, extra=[(Q_pe_shared, Pc_shared)])
            in_pos = lambda r: bq * page_size + r // heads
            cmask = AC.both(
                AC.causal(in_pos, lambda j: j),
                AC.ragged(Lens[bz], lambda j: j),
            )
            if window is not None:
                cmask = AC.both(cmask, AC.banded(in_pos, lambda j: j, window))
            ons.update(acc_c, chunk, Kc_shared, cmask)

            ons.finalize(Output[bz, bq * rows, 0])

            # ---- the paged write: this cell's chunk page, placed through
            # the block table (scalar-prefetch output BlockSpec), same
            # self-defense as prefill_attention.py: dead chunk pages land
            # in the reserved garbage page 0, table index clamped ----------
            live_page = (bq * page_size) < Lens[bz]
            tidx = T.minimum(Starts[bz] // page_size + bq, max_pages - 1)
            dst_page = T.if_then_else(live_page, Tables[bz, tidx], 0)
            T.copy(
                Kc_shared[bq * page_size : bq * page_size + page_size, :],
                KVPages[dst_page, 0, 0],
            )
            T.copy(
                Pc_shared[bq * page_size : bq * page_size + page_size, :],
                KPePages[dst_page, 0, 0],
            )

    return PrefillMLA


def mla_paged_quant_program(
    slots: int,
    heads: int,
    dim: int,
    pe_dim: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    block_H: int = 64,
    fmt: str = "int8",
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
) -> TileProgram:
    """Quantized paged MLA decode: latent *and* rope pools stored packed
    int8 with per-token scales, dequantized inline through the
    :class:`attention_core.DequantStage` composition point.  V is the
    dequantized latent — exactly the fp kernel with ``load_kv`` swapped."""
    bh = min(block_H, heads)
    if heads % bh:
        raise ValueError("the head block must divide heads")
    pack = AC.KV_PACK[fmt]
    scale = (
        sm_scale if sm_scale is not None else 1.0 / math.sqrt(dim + pe_dim)
    ) * 1.44269504  # log2(e)

    @T.prim_func
    def PagedMLAQuant(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Lens: T.ScalarTensor((slots,), "int32"),
        Q: T.Tensor((slots, heads, dim), dtype),
        Q_pe: T.Tensor((slots, heads, pe_dim), dtype),
        KVPages: T.Tensor((num_pages, page_size, dim // pack), "int8"),
        KPePages: T.Tensor((num_pages, page_size, pe_dim // pack), "int8"),
        KVScales: T.Tensor((num_pages, page_size, 1), dtype),
        KPeScales: T.Tensor((num_pages, page_size, 1), dtype),
        Output: T.Tensor((slots, heads, dim), dtype),
    ):
        with T.Kernel(heads // bh, slots) as (by, bz):
            Q_shared = T.alloc_shared((bh, dim), dtype)
            Q_pe_shared = T.alloc_shared((bh, pe_dim), dtype)
            kvq = AC.DequantStage(page_size, dim, fmt, dtype)
            peq = AC.DequantStage(page_size, pe_dim, fmt, dtype)
            acc_s = T.alloc_fragment((bh, page_size), accum_dtype)
            ons = AC.OnlineSoftmax(bh, dim, scale, accum_dtype, safe_div=True)

            T.copy(Q[bz, by * bh, 0], Q_shared)
            T.copy(Q_pe[bz, by * bh, 0], Q_pe_shared)

            def load_kv(k):
                kv = kvq.load(KVPages[Tables[bz, k], 0, 0],
                              KVScales[Tables[bz, k], 0, 0])
                peq.load(KPePages[Tables[bz, k], 0, 0],
                         KPeScales[Tables[bz, k], 0, 0])
                return kv, kv  # V is the dequantized latent itself

            def mask(k):
                return AC.ragged(Lens[bz], lambda j: k * page_size + j, window)

            AC.attend(
                ons, acc_s, page_size, max_pages, load_kv,
                lambda s, ks, k: AC.scores(
                    s, Q_shared, ks, extra=[(Q_pe_shared, peq.out)]
                ),
                mask, num_stages=num_stages,
            )
            ons.finalize(Output[bz, by * bh, 0])

    return PagedMLAQuant


def mla_prefill_quant_program(
    slots: int,
    heads: int,
    dim: int,
    pe_dim: int,
    chunk: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    fmt: str = "int8",
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
) -> TileProgram:
    """Quantized MLA chunked prefill: the chunk's latents/rope arrive
    pre-quantized (ops.py packs them), attend as the dequantized roundtrip,
    and the packed bytes + scales are written into the pools exactly as
    staged — the prefill_attention_quant composition with MLA's score
    split and the latent as V."""
    if chunk % page_size:
        raise ValueError("chunk must be a multiple of page_size")
    cpp = chunk // page_size
    rows = page_size * heads
    pack = AC.KV_PACK[fmt]
    scale = (
        sm_scale if sm_scale is not None else 1.0 / math.sqrt(dim + pe_dim)
    ) * 1.44269504  # log2(e)

    @T.prim_func
    def PrefillMLAQuant(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Starts: T.ScalarTensor((slots,), "int32"),  # prior tokens (page-aligned)
        Lens: T.ScalarTensor((slots,), "int32"),  # live tokens in the chunk
        Q: T.Tensor((slots, chunk * heads, dim), dtype),
        Q_pe: T.Tensor((slots, chunk * heads, pe_dim), dtype),
        CKV: T.Tensor((slots, chunk, dim // pack), "int8"),
        KPE: T.Tensor((slots, chunk, pe_dim // pack), "int8"),
        CKVScale: T.Tensor((slots, chunk, 1), dtype),
        KPEScale: T.Tensor((slots, chunk, 1), dtype),
        KVPages: T.Tensor((num_pages, page_size, dim // pack), "int8"),
        KPePages: T.Tensor((num_pages, page_size, pe_dim // pack), "int8"),
        KVScales: T.Tensor((num_pages, page_size, 1), dtype),
        KPeScales: T.Tensor((num_pages, page_size, 1), dtype),
        Output: T.Tensor((slots, chunk * heads, dim), dtype),
    ):
        with T.Kernel(cpp, slots) as (bq, bz):
            Q_shared = T.alloc_shared((rows, dim), dtype)
            Q_pe_shared = T.alloc_shared((rows, pe_dim), dtype)
            kc = AC.DequantStage(chunk, dim, fmt, dtype)
            pc = AC.DequantStage(chunk, pe_dim, fmt, dtype)
            kpq = AC.DequantStage(page_size, dim, fmt, dtype)
            ppq = AC.DequantStage(page_size, pe_dim, fmt, dtype)
            acc_s = T.alloc_fragment((rows, page_size), accum_dtype)
            acc_c = T.alloc_fragment((rows, chunk), accum_dtype)
            ons = AC.OnlineSoftmax(rows, dim, scale, accum_dtype,
                                   safe_div=True)

            T.copy(Q[bz, bq * rows, 0], Q_shared)
            T.copy(Q_pe[bz, bq * rows, 0], Q_pe_shared)
            Kc = kc.load(CKV[bz, 0, 0], CKVScale[bz, 0, 0])
            Pc = pc.load(KPE[bz, 0, 0], KPEScale[bz, 0, 0])

            # ---- prior latents: paged gather + inline dequant ------------
            def load_prior(kp):
                ks = kpq.load(KVPages[Tables[bz, kp], 0, 0],
                              KVScales[Tables[bz, kp], 0, 0])
                ppq.load(KPePages[Tables[bz, kp], 0, 0],
                         KPeScales[Tables[bz, kp], 0, 0])
                return ks, ks  # V is the dequantized latent itself

            q_pos = lambda r: Starts[bz] + bq * page_size + r // heads

            def prior_mask(kp):
                k_pos = lambda j: kp * page_size + j
                m = AC.ragged(Starts[bz], k_pos)
                if window is not None:
                    m = AC.both(m, AC.banded(q_pos, k_pos, window))
                return m

            AC.attend(
                ons, acc_s, page_size, max_pages, load_prior,
                lambda s, ks, kp: AC.scores(
                    s, Q_shared, ks, extra=[(Q_pe_shared, ppq.out)]
                ),
                prior_mask, num_stages=num_stages,
            )

            # ---- the chunk itself (dequantized roundtrip) ----------------
            AC.scores(acc_c, Q_shared, Kc, extra=[(Q_pe_shared, Pc)])
            in_pos = lambda r: bq * page_size + r // heads
            cmask = AC.both(
                AC.causal(in_pos, lambda j: j),
                AC.ragged(Lens[bz], lambda j: j),
            )
            if window is not None:
                cmask = AC.both(cmask, AC.banded(in_pos, lambda j: j, window))
            ons.update(acc_c, chunk, Kc, cmask)

            ons.finalize(Output[bz, bq * rows, 0])

            # ---- the paged write: packed bytes + scales as staged --------
            live_page = (bq * page_size) < Lens[bz]
            tidx = T.minimum(Starts[bz] // page_size + bq, max_pages - 1)
            dst_page = T.if_then_else(live_page, Tables[bz, tidx], 0)
            T.copy(
                kc.packed_rows(bq * page_size, bq * page_size + page_size),
                KVPages[dst_page, 0, 0],
            )
            T.copy(
                pc.packed_rows(bq * page_size, bq * page_size + page_size),
                KPePages[dst_page, 0, 0],
            )
            T.copy(
                kc.scale_shared[bq * page_size : bq * page_size + page_size, :],
                KVScales[dst_page, 0, 0],
            )
            T.copy(
                pc.scale_shared[bq * page_size : bq * page_size + page_size, :],
                KPeScales[dst_page, 0, 0],
            )

    return PrefillMLAQuant


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py): the contiguous Fig. 18 kernel, the paged decode
# kernel (ragged lens through a block table) and the chunked-prefill kernel
# (multi-page chunk, in-kernel page writes).  The paged cases take their
# inputs from the override below — tables must hold valid page ids.  The
# _quant cases store both latent and rope pools packed (int8 / int4).
PARITY_CASES = [
    (
        "mla",
        dict(batch=1, heads=4, kv_head_num=1, seqlen_kv=32, dim=16, pe_dim=8,
             block_N=16, block_H=2),
    ),
    (
        "mla_paged",
        dict(slots=3, heads=4, dim=16, pe_dim=8, page_size=16, max_pages=2,
             num_pages=8, block_H=2),
    ),
    (
        "mla_paged_windowed",
        dict(slots=3, heads=4, dim=16, pe_dim=8, page_size=16, max_pages=2,
             num_pages=8, block_H=2, window=12),
    ),
    (
        "mla_prefill",
        dict(slots=2, heads=2, dim=16, pe_dim=8, chunk=32, page_size=16,
             max_pages=4, num_pages=10),
    ),
    (
        "mla_prefill_windowed",
        dict(slots=2, heads=2, dim=16, pe_dim=8, chunk=32, page_size=16,
             max_pages=4, num_pages=10, window=20),
    ),
    (
        "mla_paged_quant_int8",
        dict(slots=3, heads=4, dim=16, pe_dim=8, page_size=16, max_pages=2,
             num_pages=8, block_H=2, fmt="int8"),
    ),
    (
        "mla_paged_quant_int4",
        dict(slots=2, heads=4, dim=16, pe_dim=8, page_size=16, max_pages=2,
             num_pages=8, block_H=2, fmt="int4"),
    ),
    (
        "mla_prefill_quant_int8",
        dict(slots=2, heads=2, dim=16, pe_dim=8, chunk=32, page_size=16,
             max_pages=4, num_pages=10, fmt="int8"),
    ),
    (
        "mla_prefill_quant_int4",
        dict(slots=2, heads=2, dim=16, pe_dim=8, chunk=32, page_size=16,
             max_pages=4, num_pages=10, fmt="int4"),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        if name == "mla":
            yield name, mla_program(**cfg)
        elif name.startswith("mla_paged_quant"):
            yield name, mla_paged_quant_program(**cfg)
        elif name.startswith("mla_paged"):
            yield name, mla_paged_program(**cfg)
        elif name.startswith("mla_prefill_quant"):
            yield name, mla_prefill_quant_program(**cfg)
        else:
            yield name, mla_prefill_program(**cfg)


def parity_inputs(name, program, rng):
    """Valid inputs for the paged parity cases: block tables drawn without
    replacement (each physical page owned by one slot), ragged lens, and —
    for the prefill kernel — page-aligned starts leaving room for the
    chunk's own pages (the serving engine's chunk contract)."""
    if name == "mla":
        return None
    cfg = dict(PARITY_CASES)[name]
    slots, mp, np_ = cfg["slots"], cfg["max_pages"], cfg["num_pages"]
    ps = cfg["page_size"]
    pages = rng.permutation(np_ - 1)[: slots * mp] + 1  # page 0 reserved
    pages = pages.reshape(slots, mp).astype("int32")
    if name.startswith("mla_paged"):
        lens = rng.integers(1, mp * ps + 1, size=slots).astype("int32")
        scalars = [pages, lens]
        nskip = 2
    else:
        chunk = cfg["chunk"]
        cpp = chunk // ps
        starts = (rng.integers(0, mp - cpp + 1, size=slots) * ps).astype("int32")
        # ragged within the last chunk page only (fully-dead chunk pages all
        # write the shared garbage page 0, whose final contents depend on
        # backend grid-walk order — same reasoning as prefill_attention.py)
        lens = rng.integers(chunk - ps + 1, chunk + 1, size=slots).astype("int32")
        scalars = [pages, starts, lens]
        nskip = 3

    def fill(p):
        if str(p.dtype).startswith("int"):
            return rng.integers(-128, 128, size=p.shape).astype(p.dtype)
        if p.name.endswith(("Scale", "Scales")):
            return rng.uniform(0.05, 0.2, size=p.shape).astype(p.dtype)
        return rng.standard_normal(p.shape).astype(p.dtype)

    args = list(scalars)
    for p in program.input_params()[nskip:]:
        args.append(fill(p))
    # in-out page pools ride after the pure inputs (aliased operands)
    for p in program.output_params():
        if p.name in ("KVPages", "KPePages", "KVScales", "KPeScales"):
            args.append(fill(p))
    return args
