"""FlashMLA in the tile DSL — a near-verbatim port of the paper's Fig. 18.

Multi-head Latent Attention (DeepSeek-V2): all query heads of a group attend
to one shared latent KV (dim) plus a rotary part (pe_dim); V is the latent
itself.  The paper reports this kernel at 98% of hand-optimized FlashMLA in
~70 lines — the headline usability result we reproduce here.
"""

import math
from typing import Optional

from repro.core import TileProgram
from repro.core import lang as T


def mla_program(
    batch: int,
    heads: int,
    kv_head_num: int,
    seqlen_kv: int,
    dim: int,
    pe_dim: int,
    block_N: int = 128,
    block_H: int = 64,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
    swizzle: Optional[int] = None,
) -> TileProgram:
    if seqlen_kv % block_N:
        raise ValueError("seqlen_kv must divide block_N")
    kv_group_num = heads // kv_head_num
    VALID_BLOCK_H = min(block_H, kv_group_num)
    if heads % VALID_BLOCK_H:
        raise ValueError("heads must divide the valid head block")
    scale = (
        sm_scale if sm_scale is not None else 1.0 / math.sqrt(dim + pe_dim)
    ) * 1.44269504  # log2(e)

    @T.prim_func
    def FlashMLA(
        Q: T.Tensor((batch, heads, dim), dtype),
        Q_pe: T.Tensor((batch, heads, pe_dim), dtype),
        KV: T.Tensor((batch, seqlen_kv, kv_head_num, dim), dtype),
        K_pe: T.Tensor((batch, seqlen_kv, kv_head_num, pe_dim), dtype),
        Output: T.Tensor((batch, heads, dim), dtype),
    ):
        with T.Kernel(batch, heads // VALID_BLOCK_H, threads=256) as (bx, by):
            Q_shared = T.alloc_shared((VALID_BLOCK_H, dim), dtype)
            S_shared = T.alloc_shared((VALID_BLOCK_H, block_N), dtype)
            Q_pe_shared = T.alloc_shared((VALID_BLOCK_H, pe_dim), dtype)
            KV_shared = T.alloc_shared((block_N, dim), dtype)
            K_pe_shared = T.alloc_shared((block_N, pe_dim), dtype)
            acc_s = T.alloc_fragment((VALID_BLOCK_H, block_N), accum_dtype)
            acc_o = T.alloc_fragment((VALID_BLOCK_H, dim), accum_dtype)
            scores_max = T.alloc_fragment((VALID_BLOCK_H,), accum_dtype)
            scores_max_prev = T.alloc_fragment((VALID_BLOCK_H,), accum_dtype)
            scores_scale = T.alloc_fragment((VALID_BLOCK_H,), accum_dtype)
            scores_sum = T.alloc_fragment((VALID_BLOCK_H,), accum_dtype)
            logsum = T.alloc_fragment((VALID_BLOCK_H,), accum_dtype)

            cur_kv_head = by // (kv_group_num // VALID_BLOCK_H)
            if swizzle:
                T.use_swizzle(swizzle)

            T.copy(Q[bx, by * VALID_BLOCK_H : (by + 1) * VALID_BLOCK_H, :], Q_shared)
            T.copy(
                Q_pe[bx, by * VALID_BLOCK_H : (by + 1) * VALID_BLOCK_H, :], Q_pe_shared
            )
            T.fill(acc_o, 0)
            T.fill(logsum, 0)
            T.fill(scores_max, -T.infinity(accum_dtype))

            loop_range = T.ceildiv(seqlen_kv, block_N)
            for k in T.Pipelined(loop_range, num_stages=num_stages):
                T.copy(
                    KV[bx, k * block_N : (k + 1) * block_N, cur_kv_head, :], KV_shared
                )
                T.copy(
                    K_pe[bx, k * block_N : (k + 1) * block_N, cur_kv_head, :],
                    K_pe_shared,
                )
                T.clear(acc_s)
                T.gemm(Q_shared, KV_shared, acc_s, transpose_B=True)
                T.gemm(Q_pe_shared, K_pe_shared, acc_s, transpose_B=True)
                T.copy(scores_max, scores_max_prev)
                T.fill(scores_max, -T.infinity(accum_dtype))
                T.reduce_max(acc_s, scores_max, dim=1, clear=False)
                neg_clamp = -1048576.0
                for i in T.Parallel(VALID_BLOCK_H):
                    scores_scale[i] = T.exp2(
                        T.maximum(scores_max_prev[i], neg_clamp) * scale
                        - scores_max[i] * scale
                    )
                for i, j in T.Parallel(VALID_BLOCK_H, block_N):
                    acc_s[i, j] = T.exp2(acc_s[i, j] * scale - scores_max[i] * scale)
                T.reduce_sum(acc_s, scores_sum, dim=1)
                T.copy(acc_s, S_shared)
                for i in T.Parallel(VALID_BLOCK_H):
                    logsum[i] = logsum[i] * scores_scale[i] + scores_sum[i]
                for i, j in T.Parallel(VALID_BLOCK_H, dim):
                    acc_o[i, j] = acc_o[i, j] * scores_scale[i]
                T.gemm(S_shared, KV_shared, acc_o)

            for i, j in T.Parallel(VALID_BLOCK_H, dim):
                acc_o[i, j] = acc_o[i, j] / logsum[i]
            T.copy(acc_o, Output[bx, by * VALID_BLOCK_H : (by + 1) * VALID_BLOCK_H, :])

    return FlashMLA


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py).
PARITY_CASES = [
    (
        "mla",
        dict(batch=1, heads=4, kv_head_num=1, seqlen_kv=32, dim=16, pe_dim=8,
             block_N=16, block_H=2),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        yield name, mla_program(**cfg)
