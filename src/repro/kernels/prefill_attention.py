"""Chunked-prefill attention in the tile DSL (the batched prompt fast path).

Processes a ``(slots, chunk)`` block of prompt tokens per launch instead of
replaying prompts one token per decode step: causal flash attention over the
chunk **plus** all prior KV gathered through the block table (the same
``T.ScalarTensor`` scalar-prefetch path as the decode kernel), while
**writing the chunk's K/V into the paged cache pages from inside the
kernel** — the stores' region starts load the block table, so the lowering
turns them into table-directed *output* BlockSpecs paired with an in-out
alias (pages no grid cell writes keep their contents).  This is the output
half of "plan dataflow over non-contiguous tiles as a one-line index
change": producer blocks stay ``chunk`` tokens wide and the tile pipeline
stays saturated, which is where the serving win comes from (ThunderKittens'
large-producer-block observation applied to prefill).

Grid: ``(kv_heads, chunk_pages, slots)`` with the prior-KV page axis
pipelined.  Queries are packed chunk-major with their GQA group —
``Q[z, h, i * group + g, :]`` is chunk position ``i`` of query head
``h * group + g`` — so each grid cell attends a ``(page_size * group,
head_dim)`` query tile with plain 2-D GEMMs (the decode kernel's
``(group, head_dim)`` trick extended to a block of positions).

Contract (the serving engine guarantees it; parity inputs too):
``chunk % page_size == 0`` and every *live* slot's ``Starts`` is
page-aligned — chunks are fed at ``chunk`` boundaries, so this holds
whenever ``chunk`` is a multiple of the page size.  Everything else is
self-defending: chunk pages holding no live tokens (``lens = 0`` slots
riding in a batched engine tick, the dead tail of a partial final chunk)
write to the reserved garbage page 0, and the table index is clamped to
the row, so an idle slot's arbitrary ``Starts`` can neither read out of
bounds nor clobber a live page.  Live positions past a slot's allocation
hit table padding (page 0) harmlessly.
"""

import math
from typing import Optional

from repro.core import TileProgram
from repro.core import lang as T

from . import attention_core as AC


def prefill_attention_program(
    slots: int,
    heads: int,
    kv_heads: int,
    head_dim: int,
    chunk: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    window: Optional[int] = None,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
) -> TileProgram:
    if heads % kv_heads:
        raise ValueError("GQA requires heads % kv_heads == 0")
    if chunk % page_size:
        raise ValueError("chunk must be a multiple of page_size")
    group = heads // kv_heads
    cpp = chunk // page_size  # chunk pages: K/V pages written per slot
    rows = page_size * group  # query rows per grid cell (chunk-major packed)
    scale = (sm_scale if sm_scale is not None else 1.0 / math.sqrt(head_dim)) * 1.44269504  # log2(e)

    @T.prim_func
    def PrefillAttn(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Starts: T.ScalarTensor((slots,), "int32"),  # prior tokens (page-aligned)
        Lens: T.ScalarTensor((slots,), "int32"),  # live tokens in the chunk
        Q: T.Tensor((slots, kv_heads, chunk * group, head_dim), dtype),
        K: T.Tensor((slots, kv_heads, chunk, head_dim), dtype),
        V: T.Tensor((slots, kv_heads, chunk, head_dim), dtype),
        KPages: T.Tensor((kv_heads, num_pages, page_size, head_dim), dtype),
        VPages: T.Tensor((kv_heads, num_pages, page_size, head_dim), dtype),
        Output: T.Tensor((slots, kv_heads, chunk * group, head_dim), dtype),
    ):
        with T.Kernel(kv_heads, cpp, slots) as (bh, bq, bz):
            Q_shared = T.alloc_shared((rows, head_dim), dtype)
            Kc_shared = T.alloc_shared((chunk, head_dim), dtype)
            Vc_shared = T.alloc_shared((chunk, head_dim), dtype)
            Kp_shared = T.alloc_shared((page_size, head_dim), dtype)
            Vp_shared = T.alloc_shared((page_size, head_dim), dtype)
            acc_s = T.alloc_fragment((rows, page_size), accum_dtype)
            acc_c = T.alloc_fragment((rows, chunk), accum_dtype)
            # safe_div: rows past Lens are fully masked -> zeros, not nan
            ons = AC.OnlineSoftmax(rows, head_dim, scale, accum_dtype,
                                   safe_div=True)

            T.copy(Q[bz, bh, bq * rows, 0], Q_shared)
            T.copy(K[bz, bh, 0, 0], Kc_shared)
            T.copy(V[bz, bh, 0, 0], Vc_shared)

            # the absolute position of query row r (chunk-major packing)
            q_pos = lambda r: Starts[bz] + bq * page_size + r // group

            # ---- prior KV, gathered through the block table --------------
            def load_prior(kp):
                T.copy(KPages[bh, Tables[bz, kp], 0, 0], Kp_shared)
                T.copy(VPages[bh, Tables[bz, kp], 0, 0], Vp_shared)
                return Kp_shared, Vp_shared

            def prior_mask(kp):
                # prior positions [0, Starts) are live; everything else
                # (the chunk's own pages, table padding) is masked.
                k_pos = lambda j: kp * page_size + j
                m = AC.ragged(Starts[bz], k_pos)
                if window is not None:
                    m = AC.both(m, AC.banded(q_pos, k_pos, window))
                return m

            AC.attend(
                ons, acc_s, page_size, max_pages, load_prior,
                lambda s, ks, k: AC.scores(s, Q_shared, ks), prior_mask,
                num_stages=num_stages,
            )

            # ---- the chunk itself (keys straight from the K/V inputs —
            # never read back through the pages we are writing): causal over
            # in-chunk positions, ragged against the live length ------------
            AC.scores(acc_c, Q_shared, Kc_shared)
            in_pos = lambda r: bq * page_size + r // group
            cmask = AC.both(
                AC.causal(in_pos, lambda j: j),
                AC.ragged(Lens[bz], lambda j: j),
            )
            if window is not None:
                cmask = AC.both(cmask, AC.banded(in_pos, lambda j: j, window))
            ons.update(acc_c, chunk, Vc_shared, cmask)

            ons.finalize(Output[bz, bh, bq * rows, 0])

            # ---- the paged write: this cell's chunk page, placed through
            # the block table (scalar-prefetch output BlockSpec).  The write
            # is self-defending: chunk pages with no live tokens (idle
            # lens=0 slots riding in the batch, the dead tail of a partial
            # final chunk) land in the reserved garbage page 0, and the
            # table index is clamped so an idle slot's arbitrary ``Starts``
            # can never read past its table row. ---------------------------
            live_page = (bq * page_size) < Lens[bz]
            tidx = T.minimum(Starts[bz] // page_size + bq, max_pages - 1)
            dst_page = T.if_then_else(live_page, Tables[bz, tidx], 0)
            T.copy(
                Kc_shared[bq * page_size : bq * page_size + page_size, :],
                KPages[bh, dst_page, 0, 0],
            )
            T.copy(
                Vc_shared[bq * page_size : bq * page_size + page_size, :],
                VPages[bh, dst_page, 0, 0],
            )

    return PrefillAttn


def prefill_attention_quant_program(
    slots: int,
    heads: int,
    kv_heads: int,
    head_dim: int,
    chunk: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    fmt: str = "int8",
    window: Optional[int] = None,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
) -> TileProgram:
    """Quantized chunked prefill: the fp kernel with both KV paths routed
    through :class:`attention_core.DequantStage`.

    The chunk's own K/V arrive *pre-quantized* (packed int8 + per-token
    scales — ops.py quantizes at the jnp level before the call) so the
    paged write stores exactly the bytes that were staged: the packed
    shared slices and scale slices are copied straight into the packed
    pools and scale pools through the block table, and the chunk's own
    attention reads the dequantized roundtrip (what every later decode
    step will see).  Prior pages dequantize page-at-a-time as in the
    quantized decode kernel."""
    if heads % kv_heads:
        raise ValueError("GQA requires heads % kv_heads == 0")
    if chunk % page_size:
        raise ValueError("chunk must be a multiple of page_size")
    group = heads // kv_heads
    cpp = chunk // page_size
    rows = page_size * group
    pack = AC.KV_PACK[fmt]
    scale = (sm_scale if sm_scale is not None else 1.0 / math.sqrt(head_dim)) * 1.44269504  # log2(e)

    @T.prim_func
    def PrefillAttnQuant(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Starts: T.ScalarTensor((slots,), "int32"),  # prior tokens (page-aligned)
        Lens: T.ScalarTensor((slots,), "int32"),  # live tokens in the chunk
        Q: T.Tensor((slots, kv_heads, chunk * group, head_dim), dtype),
        K: T.Tensor((slots, kv_heads, chunk, head_dim // pack), "int8"),
        V: T.Tensor((slots, kv_heads, chunk, head_dim // pack), "int8"),
        KScale: T.Tensor((slots, kv_heads, chunk, 1), dtype),
        VScale: T.Tensor((slots, kv_heads, chunk, 1), dtype),
        KPages: T.Tensor((kv_heads, num_pages, page_size, head_dim // pack), "int8"),
        VPages: T.Tensor((kv_heads, num_pages, page_size, head_dim // pack), "int8"),
        KScales: T.Tensor((kv_heads, num_pages, page_size, 1), dtype),
        VScales: T.Tensor((kv_heads, num_pages, page_size, 1), dtype),
        Output: T.Tensor((slots, kv_heads, chunk * group, head_dim), dtype),
    ):
        with T.Kernel(kv_heads, cpp, slots) as (bh, bq, bz):
            Q_shared = T.alloc_shared((rows, head_dim), dtype)
            kc = AC.DequantStage(chunk, head_dim, fmt, dtype)
            vc = AC.DequantStage(chunk, head_dim, fmt, dtype)
            kp = AC.DequantStage(page_size, head_dim, fmt, dtype)
            vp = AC.DequantStage(page_size, head_dim, fmt, dtype)
            acc_s = T.alloc_fragment((rows, page_size), accum_dtype)
            acc_c = T.alloc_fragment((rows, chunk), accum_dtype)
            # safe_div: rows past Lens are fully masked -> zeros, not nan
            ons = AC.OnlineSoftmax(rows, head_dim, scale, accum_dtype,
                                   safe_div=True)

            T.copy(Q[bz, bh, bq * rows, 0], Q_shared)
            # stage + dequantize the chunk once (the roundtrip every later
            # decode step will read back from the pages)
            Kc = kc.load(K[bz, bh, 0, 0], KScale[bz, bh, 0, 0])
            Vc = vc.load(V[bz, bh, 0, 0], VScale[bz, bh, 0, 0])

            q_pos = lambda r: Starts[bz] + bq * page_size + r // group

            # ---- prior KV: paged gather + inline dequant -----------------
            def load_prior(kpg):
                ks = kp.load(KPages[bh, Tables[bz, kpg], 0, 0],
                             KScales[bh, Tables[bz, kpg], 0, 0])
                vs = vp.load(VPages[bh, Tables[bz, kpg], 0, 0],
                             VScales[bh, Tables[bz, kpg], 0, 0])
                return ks, vs

            def prior_mask(kpg):
                k_pos = lambda j: kpg * page_size + j
                m = AC.ragged(Starts[bz], k_pos)
                if window is not None:
                    m = AC.both(m, AC.banded(q_pos, k_pos, window))
                return m

            AC.attend(
                ons, acc_s, page_size, max_pages, load_prior,
                lambda s, ks, k: AC.scores(s, Q_shared, ks), prior_mask,
                num_stages=num_stages,
            )

            # ---- the chunk itself (dequantized roundtrip, never read back
            # through the pages being written) -----------------------------
            AC.scores(acc_c, Q_shared, Kc)
            in_pos = lambda r: bq * page_size + r // group
            cmask = AC.both(
                AC.causal(in_pos, lambda j: j),
                AC.ragged(Lens[bz], lambda j: j),
            )
            if window is not None:
                cmask = AC.both(cmask, AC.banded(in_pos, lambda j: j, window))
            ons.update(acc_c, chunk, Vc, cmask)

            ons.finalize(Output[bz, bh, bq * rows, 0])

            # ---- the paged write: packed bytes + scales, exactly as they
            # were staged (same table-directed self-defense as the fp
            # kernel: dead chunk pages land in garbage page 0) --------------
            live_page = (bq * page_size) < Lens[bz]
            tidx = T.minimum(Starts[bz] // page_size + bq, max_pages - 1)
            dst_page = T.if_then_else(live_page, Tables[bz, tidx], 0)
            T.copy(
                kc.packed_rows(bq * page_size, bq * page_size + page_size),
                KPages[bh, dst_page, 0, 0],
            )
            T.copy(
                vc.packed_rows(bq * page_size, bq * page_size + page_size),
                VPages[bh, dst_page, 0, 0],
            )
            T.copy(
                kc.scale_shared[bq * page_size : bq * page_size + page_size, :],
                KScales[bh, dst_page, 0, 0],
            )
            T.copy(
                vc.scale_shared[bq * page_size : bq * page_size + page_size, :],
                VScales[bh, dst_page, 0, 0],
            )

    return PrefillAttnQuant


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py): MQA grouping, a multi-page chunk under GQA, and
# a sliding window.  Inputs come from the override below — tables must hold
# distinct live page ids and starts must be page-aligned.  The _quant cases
# route both KV paths through the DequantStage and write packed pages.
PARITY_CASES = [
    (
        "prefill_attention_mqa",
        dict(slots=2, heads=2, kv_heads=1, head_dim=16, chunk=16,
             page_size=16, max_pages=4, num_pages=8),
    ),
    (
        "prefill_attention_gqa_multipage",
        dict(slots=2, heads=4, kv_heads=2, head_dim=16, chunk=32,
             page_size=16, max_pages=4, num_pages=8),
    ),
    (
        "prefill_attention_windowed",
        dict(slots=2, heads=2, kv_heads=2, head_dim=16, chunk=16,
             page_size=16, max_pages=4, num_pages=8, window=20),
    ),
    (
        "prefill_attention_quant_int8",
        dict(slots=2, heads=4, kv_heads=2, head_dim=16, chunk=32,
             page_size=16, max_pages=4, num_pages=8, fmt="int8"),
    ),
    (
        "prefill_attention_quant_int4",
        dict(slots=2, heads=2, kv_heads=1, head_dim=16, chunk=16,
             page_size=16, max_pages=4, num_pages=8, fmt="int4"),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        maker = prefill_attention_quant_program if "quant" in name else prefill_attention_program
        yield name, maker(**cfg)


def parity_inputs(name, program, rng):
    """Valid inputs for the parity suite.

    Every slot gets a distinct set of physical pages, a page-aligned prior
    length leaving room for the chunk's pages, and a ragged live length
    (including a partial chunk).
    """
    cfg = dict(PARITY_CASES)[name]
    slots, mp, np_ = cfg["slots"], cfg["max_pages"], cfg["num_pages"]
    ps, chunk = cfg["page_size"], cfg["chunk"]
    cpp = chunk // ps
    pages = rng.permutation(np_)[: slots * mp].reshape(slots, mp).astype("int32")
    prior_pages = rng.integers(0, mp - cpp + 1, size=slots)
    starts = (prior_pages * ps).astype("int32")
    # ragged within the *last* chunk page only: fully-dead chunk pages all
    # write the shared garbage page 0, whose final contents depend on grid
    # walk order — backend-dependent, so parity keeps every page live (the
    # dead-page path is covered by tests/test_prefill.py, which excludes
    # page 0 from comparison).
    lens = rng.integers(chunk - ps + 1, chunk + 1, size=slots).astype("int32")

    def fill(p):
        if str(p.dtype).startswith("int"):
            return rng.integers(-128, 128, size=p.shape).astype(p.dtype)
        if p.name.endswith(("Scale", "Scales")):
            return rng.uniform(0.05, 0.2, size=p.shape).astype(p.dtype)
        return rng.standard_normal(p.shape).astype(p.dtype)

    args = [pages, starts, lens]
    for p in program.input_params()[3:]:
        args.append(fill(p))
    # in-out page pools ride after the pure inputs (aliased operands)
    for p in program.output_params():
        if p.name in ("KPages", "VPages", "KScales", "VScales"):
            args.append(fill(p))
    return args
