# Tile-DSL kernels (paper §5 workloads) + jit'd wrappers + jnp oracles.
from . import ops, ref
from .dequant_matmul import dequant_matmul_program
from .flash_attention import flash_attention_program
from .linear_attention import chunk_scan_program, chunk_state_program
from .matmul import matmul_program, tune_matmul
from .mla import mla_program

__all__ = [
    "ops",
    "ref",
    "matmul_program",
    "tune_matmul",
    "flash_attention_program",
    "mla_program",
    "dequant_matmul_program",
    "chunk_state_program",
    "chunk_scan_program",
]
