# Tile-DSL kernels (paper §5 workloads) + jit'd wrappers + jnp oracles.
from . import ops, ref
from .dequant_matmul import dequant_matmul_program
from .flash_attention import flash_attention_program
from .linear_attention import chunk_scan_program, chunk_state_program
from .matmul import matmul_program, tune_matmul
from .mla import mla_program


def parity_programs():
    """Yield ``(name, TileProgram)`` for every kernel at tiny shapes.

    One entry per ``PARITY_CASES`` item in each kernel module; the
    backend-parity suite (tests/test_pipeline.py) compiles each program with
    both ``target="pallas"`` (interpret mode) and ``target="reference"`` and
    asserts numerical agreement.
    """
    from . import dequant_matmul, flash_attention, linear_attention, matmul, mla

    for mod in (matmul, flash_attention, mla, dequant_matmul, linear_attention):
        yield from mod.parity_programs()


__all__ = [
    "ops",
    "ref",
    "matmul_program",
    "tune_matmul",
    "flash_attention_program",
    "mla_program",
    "dequant_matmul_program",
    "chunk_state_program",
    "chunk_scan_program",
    "parity_programs",
]
