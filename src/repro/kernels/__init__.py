# Tile-DSL kernels (paper §5 workloads) + jit'd wrappers + jnp oracles.
import importlib
import pkgutil

from . import (
    attention_core,
    dequant_matmul,
    flash_attention,
    linear_attention,
    matmul,
    mla,
    ops,
    paged_attention,
    prefill_attention,
    ref,
)
from .dequant_matmul import dequant_matmul_program
from .flash_attention import flash_attention_program
from .linear_attention import chunk_scan_program, chunk_state_program
from .matmul import matmul_program, tune_matmul
from .mla import (
    mla_paged_program,
    mla_paged_quant_program,
    mla_prefill_program,
    mla_prefill_quant_program,
    mla_program,
)
from .paged_attention import paged_attention_program, paged_attention_quant_program
from .prefill_attention import (
    prefill_attention_program,
    prefill_attention_quant_program,
)


def parity_modules():
    """Every module in ``repro.kernels`` that declares ``PARITY_CASES``.

    Auto-discovered from the package contents (no hand-kept list): a new
    kernel module is covered by the backend-parity suite the moment it
    defines ``PARITY_CASES`` — coverage by construction.  Sorted by module
    name so the suite's parametrization order is deterministic.
    """
    mods = []
    for info in pkgutil.iter_modules(__path__):
        mod = importlib.import_module(f"{__name__}.{info.name}")
        if hasattr(mod, "PARITY_CASES"):
            mods.append(mod)
    return sorted(mods, key=lambda m: m.__name__)


def parity_programs():
    """Yield ``(name, TileProgram)`` for every kernel at tiny shapes.

    One entry per ``PARITY_CASES`` item in each kernel module; the
    backend-parity suite (tests/test_pipeline.py) compiles each program with
    both ``target="pallas"`` (interpret mode) and ``target="reference"`` and
    asserts numerical agreement.
    """
    for mod in parity_modules():
        yield from mod.parity_programs()


def parity_inputs(name, program, rng):
    """Inputs for one parity case, or ``None`` for the generic random fill.

    Kernel modules whose params carry semantic constraints (paged
    attention's block tables must hold valid page ids) define a
    ``parity_inputs(name, program, rng)`` hook; everything else gets
    unconstrained random tensors from the parity suite itself.
    """
    for mod in parity_modules():
        hook = getattr(mod, "parity_inputs", None)
        if hook is not None and name in dict(mod.PARITY_CASES):
            return hook(name, program, rng)
    return None


__all__ = [
    "ops",
    "ref",
    "attention_core",
    "matmul_program",
    "tune_matmul",
    "flash_attention_program",
    "mla_program",
    "mla_paged_program",
    "mla_paged_quant_program",
    "mla_prefill_program",
    "mla_prefill_quant_program",
    "paged_attention_program",
    "paged_attention_quant_program",
    "prefill_attention_program",
    "prefill_attention_quant_program",
    "dequant_matmul_program",
    "chunk_state_program",
    "chunk_scan_program",
    "parity_modules",
    "parity_programs",
    "parity_inputs",
]
