"""Weight-only-quantized GEMM in the tile DSL (paper Fig. 15/17).

W_{INT4|INT2|NF4} A_{FP16/FP32}: the packed weight tile streams through a
shared window, is unpacked to compute dtype *inside the kernel* by a
vectorized elementwise body, then hits the MXU.  The unpack runs on the VPU
with shift/mask arithmetic over int lanes — the TPU analogue of the PTX
``lop3``-based fast dtype conversion the paper cites ([15], Ladder [21]).

NF4 uses the tile-library escape hatch (``T.call_tile_lib``) for its 16-entry
codebook lookup — the same role ``T.import_source``/``T.ptx`` play on GPUs.
"""

from typing import Optional

import jax.numpy as jnp

from repro.core import TileProgram
from repro.core import lang as T

from . import ref as _ref

_PACK = {"int4": 2, "int2": 4, "nf4": 2, "int8": 1}


def dequant_matmul_program(
    M: int,
    N: int,
    K: int,
    fmt: str = "int4",
    in_dtype: str = "float32",
    out_dtype: str = "float32",
    accum_dtype: str = "float32",
    block_M: int = 64,
    block_N: int = 64,
    block_K: int = 64,
    num_stages: int = 2,
    with_scales: bool = False,
) -> TileProgram:
    """C^T[N, M] = dequant(B)[N, K] @ A[M, K]^T  (paper's transposed layout)."""
    if fmt not in _PACK:
        raise ValueError(f"unknown quant format {fmt}")
    pack = _PACK[fmt]
    if block_K % pack:
        raise ValueError("block_K must be a multiple of the pack factor")
    storage_dtype = "int8"
    if M % block_M or N % block_N or K % block_K:
        raise ValueError("blocks must divide problem shape")

    params = dict(
        A=T.Tensor((M, K), in_dtype),
        B=T.Tensor((N, K // pack), storage_dtype),
        Ct=T.Tensor((N, M), out_dtype),
    )
    if with_scales:
        params["Scales"] = T.Tensor((N, K // block_K), in_dtype)

    def body(A, B, Ct, Scales=None):
        with T.Kernel(T.ceildiv(N, block_N), T.ceildiv(M, block_M), threads=128) as (bx, by):
            A_shared = T.alloc_shared((block_M, block_K), in_dtype)
            B_shared = T.alloc_shared((block_N, block_K // pack), storage_dtype)
            B_local = T.alloc_fragment((block_N, block_K // pack), storage_dtype)
            B_dequant = T.alloc_fragment((block_N, block_K), in_dtype)
            Ct_local = T.alloc_fragment((block_N, block_M), accum_dtype)
            if with_scales:
                S_shared = T.alloc_shared((block_N, 1), in_dtype)

            T.clear(Ct_local)
            for k in T.Pipelined(T.ceildiv(K, block_K), num_stages=num_stages):
                T.copy(A[by * block_M, k * block_K], A_shared)
                T.copy(B[bx * block_N, k * (block_K // pack)], B_shared)
                if with_scales:
                    T.copy(Scales[bx * block_N, k], S_shared)
                T.copy(B_shared, B_local)
                if fmt == "int4":
                    for i, j in T.Parallel(block_N, block_K):
                        v = (B_local[i, j // 2] >> ((j % 2) * 4)) & 15
                        v = T.if_then_else(v >= 8, v - 16, v)
                        B_dequant[i, j] = T.cast(v, in_dtype)
                elif fmt == "int2":
                    for i, j in T.Parallel(block_N, block_K):
                        v = (B_local[i, j // 4] >> ((j % 4) * 2)) & 3
                        v = T.if_then_else(v >= 2, v - 4, v)
                        B_dequant[i, j] = T.cast(v, in_dtype)
                elif fmt == "int8":
                    for i, j in T.Parallel(block_N, block_K):
                        B_dequant[i, j] = T.cast(B_local[i, j], in_dtype)
                else:  # nf4: codebook via the tile-library escape hatch

                    def _nf4_decode(packed):
                        # scalar select-chain: array constants cannot be
                        # captured by a Pallas kernel, so the 16-entry
                        # codebook is inlined as scalar immediates (the VPU
                        # analogue of an in-register LUT).
                        idx = jnp.stack(
                            [packed & 0xF, (packed >> 4) & 0xF], axis=-1
                        ).reshape(packed.shape[0], -1)
                        out = jnp.zeros(idx.shape, jnp.float32)
                        for i, val in enumerate(_ref.NF4_CODEBOOK.tolist()):
                            out = jnp.where(idx == i, jnp.float32(val), out)
                        return out.astype(jnp.dtype(in_dtype))

                    T.call_tile_lib(_nf4_decode, B_dequant, B_local, name="nf4_decode")
                if with_scales:
                    for i, j in T.Parallel(block_N, block_K):
                        B_dequant[i, j] = B_dequant[i, j] * S_shared[i, 0]
                T.gemm(B_dequant, A_shared, Ct_local, transpose_B=True)
            T.copy(Ct_local, Ct[bx * block_N, by * block_M])

    # build a prim_func with the right signature (scales optional)
    if with_scales:

        def fn(
            A: params["A"], B: params["B"], Ct: params["Ct"], Scales: params["Scales"]
        ):
            body(A, B, Ct, Scales)

    else:

        def fn(A: params["A"], B: params["B"], Ct: params["Ct"]):
            body(A, B, Ct)

    fn.__name__ = f"dequant_matmul_{fmt}"
    fn.__annotations__ = {k: v for k, v in params.items()}
    return T.prim_func(fn)


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py); int4 exercises the vectorized sub-byte unpack,
# int8 the straight cast path, int2 the 4-way sub-byte unpack, nf4 the
# codebook lookup via the T.call_tile_lib escape hatch.  The odd-K int4
# case (K=48 -> 3 K-blocks) covers shapes the old K % (block_K * pack)
# guard wrongly rejected.
PARITY_CASES = [
    (
        "dequant_matmul_int4",
        dict(M=16, N=16, K=32, fmt="int4", block_M=16, block_N=16, block_K=16),
    ),
    (
        "dequant_matmul_int4_oddk",
        dict(M=16, N=16, K=48, fmt="int4", block_M=16, block_N=16, block_K=16),
    ),
    (
        "dequant_matmul_int8",
        dict(M=16, N=16, K=32, fmt="int8", block_M=16, block_N=16, block_K=16),
    ),
    (
        "dequant_matmul_int2",
        dict(M=16, N=16, K=32, fmt="int2", block_M=16, block_N=16, block_K=16),
    ),
    (
        "dequant_matmul_nf4",
        dict(M=16, N=16, K=32, fmt="nf4", block_M=16, block_N=16, block_K=16),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        yield name, dequant_matmul_program(**cfg)
