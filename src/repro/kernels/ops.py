"""Public kernel API: jit'd wrappers dispatching tile-DSL Pallas kernels or
the pure-jnp reference (XLA) path.

Backend selection (``kernel_backend``):

* ``"pallas"`` — compile the tile-DSL program via repro.core.  On CPU hosts
  the kernel runs in Pallas interpreter mode (bit-faithful to the TPU
  lowering's dataflow); on TPU it is the Mosaic-compiled kernel.
* ``"xla"``    — the ref.py oracle, letting XLA fuse (used by the model layer
  for the multi-pod dry-run, where kernels must trace through SPMD
  partitioning).
* ``"auto"``   — pallas on TPU, xla elsewhere.

Compiled tile kernels are cached per (kernel, static config) — the TPU
realization of the paper's "dynamic parameter simplification" for kernel
libraries: a library entry recompiles per shape bucket and reuses the cached
schedule.  The local dict below only skips *re-tracing* the program factory;
the compile itself is additionally memoized inside repro.core.compiler on
(program fingerprint, schedule, target), shared with autotune and serving.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import Schedule, compile as tl_compile

from . import ref
from .dequant_matmul import dequant_matmul_program
from .flash_attention import flash_attention_program
from .linear_attention import chunk_scan_program, chunk_state_program
from .matmul import matmul_program
from .mla import (
    mla_paged_program,
    mla_paged_quant_program,
    mla_prefill_program,
    mla_prefill_quant_program,
    mla_program,
)
from .paged_attention import paged_attention_program, paged_attention_quant_program
from .prefill_attention import (
    prefill_attention_program,
    prefill_attention_quant_program,
)

_DEFAULT = os.environ.get("REPRO_KERNEL_BACKEND", "auto")
_CACHE: dict = {}

# Obligation kinds (core.lowering.verify.Obligation) that guard_dispatch
# discharges.  A future kernel emitting a new kind must either extend the
# guard or keep the obligation out of the serving dispatch path; the test
# suite asserts every paged kernel's obligations stay within this set.
GUARDED_KINDS = frozenset({"table_in_range", "table_writes_disjoint"})


def guard_dispatch(tables, num_pages, page_size, work):
    """Discharge the static verifier's runtime obligations for one paged
    dispatch, before any page is read or written.

    ``tables`` is the (rows, max_pages) block table, ``num_pages`` the pool
    extent on the page axis (page 0 reserved as the garbage sink), and
    ``work`` an iterable of ``(row, read_end, write_begin, write_end)``
    token positions: the row will read KV for positions ``[0, read_end)``
    and write positions ``[write_begin, write_end)``.

    Checks (cheap, host-side, O(tokens) ints):

    * capacity — ``read_end``/``write_end`` within ``max_pages*page_size``;
    * ``table_in_range`` — every entry backing a live position lies in
      ``[1, num_pages)`` (0 is the reserved sink: a live position mapped
      there would read garbage or lose its write);
    * ``table_writes_disjoint`` — no page is written by two rows, written
      twice within a row, or written by one row while live in another.

    All violations are collected and raised as one :class:`GuardError`
    (``.violations`` = list of ``(row, kind, message)``) so a batch
    dispatcher can fail exactly the offending rows and keep the rest.
    """
    import numpy as np

    from repro.core.errors import GuardError

    tb = np.asarray(tables)
    max_pages = tb.shape[1]
    capacity = max_pages * page_size
    violations = []
    live: dict = {}  # row -> np entries backing positions [0, read_end)
    writes: dict = {}  # row -> np entries written in [write_begin, write_end)
    for row, read_end, wbeg, wend in work:
        if read_end > capacity or wend > capacity:
            violations.append(
                (row, "table_in_range",
                 f"length {max(read_end, wend)} exceeds page capacity "
                 f"{capacity} ({max_pages} pages x {page_size})")
            )
            continue
        n_live = -(-int(read_end) // page_size)
        entries = tb[row, :n_live].astype(np.int64)
        bad = np.flatnonzero((entries < 1) | (entries >= num_pages))
        if bad.size:
            j = int(bad[0])
            violations.append(
                (row, "table_in_range",
                 f"entry {j} is page {int(entries[j])}, not in "
                 f"[1, {num_pages}) (page 0 is the reserved sink)")
            )
            continue
        live[row] = entries
        if wend > wbeg:
            pbeg, pend = int(wbeg) // page_size, -(-int(wend) // page_size)
            writes[row] = tb[row, pbeg:pend].astype(np.int64)

    writer_of: dict = {}  # page -> first writer row
    bad_rows = set()
    for row, pages in writes.items():
        for pg in pages.tolist():
            other = writer_of.get(pg)
            if other is not None and (other != row or
                                      pages.tolist().count(pg) > 1):
                for r in {row, other} - bad_rows:
                    violations.append(
                        (r, "table_writes_disjoint",
                         f"page {pg} written by rows {other} and {row}")
                    )
                bad_rows.update({row, other})
            else:
                writer_of[pg] = row
    for row, pages in writes.items():
        if row in bad_rows:
            continue
        pset = set(pages.tolist())
        for other, lv in live.items():
            if other == row:
                continue
            shared = pset.intersection(lv.tolist())
            if shared:
                violations.append(
                    (row, "table_writes_disjoint",
                     f"page {sorted(shared)[0]} written by row {row} while "
                     f"live in row {other}")
                )
                bad_rows.add(row)
                break
    if violations:
        raise GuardError(violations)


def default_backend() -> str:
    if _DEFAULT != "auto":
        return _DEFAULT
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _cached(key, builder):
    if key not in _CACHE:
        _CACHE[key] = tl_compile(builder(), Schedule(interpret=_interpret()))
    return _CACHE[key]


def _resolve(backend: Optional[str]) -> str:
    return backend or default_backend()


def _pick_block(n: int, candidates=(128, 64, 32, 16, 8)) -> int:
    for c in candidates:
        if n % c == 0:
            return c
    return n


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------


def matmul(a, b, *, out_dtype=None, backend: Optional[str] = None,
           block_m: Optional[int] = None, block_n: Optional[int] = None,
           block_k: Optional[int] = None, num_stages: int = 2):
    out_dtype = out_dtype or a.dtype
    if _resolve(backend) == "xla":
        return ref.matmul(a, b, out_dtype)
    M, K = a.shape
    _, N = b.shape
    bm = block_m or _pick_block(M)
    bn = block_n or _pick_block(N)
    bk = block_k or _pick_block(K, (256, 128, 64, 32, 16, 8))
    key = ("matmul", M, N, K, str(a.dtype), str(out_dtype), bm, bn, bk, num_stages)
    kern = _cached(
        key,
        lambda: matmul_program(
            M, N, K, str(a.dtype), str(jnp.dtype(out_dtype)), "float32",
            bm, bn, bk, num_stages,
        ),
    )
    return kern(a, b)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(q, k, v, *, causal: bool = False, sm_scale=None,
              backend: Optional[str] = None, block_m: Optional[int] = None,
              block_n: Optional[int] = None, num_stages: int = 2, **xla_kw):
    be = _resolve(backend)
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    bm = block_m or _pick_block(sq)
    bn = block_n or _pick_block(sk)
    if (
        be == "xla"
        or xla_kw.get("window") is not None
        or xla_kw.get("kv_len") is not None
        or xla_kw.get("logit_soft_cap") is not None
    ):
        return ref.attention(q, k, v, causal=causal, sm_scale=sm_scale, **xla_kw)
    key = ("fa", b, hq, hkv, sq, sk, d, causal, str(q.dtype), bm, bn,
           num_stages, sm_scale)
    kern = _cached(
        key,
        lambda: flash_attention_program(
            b, hq, hkv, sq, sk, d, causal, bm, bn, str(q.dtype), "float32",
            num_stages, sm_scale,
        ),
    )
    return kern(q, k, v)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    sm_scale=None, window: Optional[int] = None,
                    logit_soft_cap=None, backend: Optional[str] = None,
                    num_stages: int = 2):
    """Single-token decode attention over a paged KV pool (see
    kernels/paged_attention.py for shapes).  The Pallas path gathers pages
    through the block table via scalar prefetch; the XLA path is
    ref.paged_attention (used by the serving engine on CPU hosts)."""
    be = _resolve(backend)
    if be == "xla" or logit_soft_cap is not None:
        return ref.paged_attention(
            q, k_pages, v_pages, block_tables, seq_lens, sm_scale=sm_scale,
            window=window, logit_soft_cap=logit_soft_cap,
        )
    b, hq, d = q.shape
    hkv, num_pages, page_size, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    key = ("paged", b, hq, hkv, num_pages, page_size, max_pages, d, window,
           str(q.dtype), num_stages, sm_scale)
    kern = _cached(
        key,
        lambda: paged_attention_program(
            b, hq, hkv, d, page_size, max_pages, num_pages, window,
            str(q.dtype), "float32", num_stages, sm_scale,
        ),
    )
    return kern(block_tables, seq_lens, q, k_pages, v_pages)


def prefill_attention(q, k_new, v_new, k_pages, v_pages, block_tables,
                      start_lens, chunk_lens, *, sm_scale=None,
                      window: Optional[int] = None, logit_soft_cap=None,
                      backend: Optional[str] = None, num_stages: int = 2):
    """Chunked-prefill attention over a paged KV pool.

    ``q``/``k_new``/``v_new`` are the chunk's (B, H*, C, D) projections;
    ``start_lens`` (B,) counts prior resident tokens (the chunk's write
    offset) and ``chunk_lens`` (B,) the live tokens within the chunk.
    Returns ``(out, k_pages', v_pages')`` — the chunk's K/V are written into
    the pool pages through the block table, positions past ``chunk_lens``
    landing in the reserved garbage page 0.

    The Pallas path runs the tile kernel, which performs the page writes
    from inside the kernel via table-directed output BlockSpecs; it
    additionally requires chunk-aligned ``start_lens`` and in-range table
    entries (the serving engine's chunk contract).  The XLA path is the
    ref.prefill_attention oracle plus an explicit masked scatter.
    """
    be = _resolve(backend)
    b, hq, chunk, d = q.shape
    hkv, num_pages, page_size, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    if be != "xla" and logit_soft_cap is None and chunk % page_size == 0 \
            and chunk // page_size <= max_pages:
        group = hq // hkv
        key = ("prefill", b, hq, hkv, num_pages, page_size, max_pages, chunk,
               d, window, str(q.dtype), num_stages, sm_scale)
        kern = _cached(
            key,
            lambda: prefill_attention_program(
                b, hq, hkv, d, chunk, page_size, max_pages, num_pages, window,
                str(q.dtype), "float32", num_stages, sm_scale,
            ),
        )
        # pack queries chunk-major with their GQA group: row = i*group + g
        qp = q.reshape(b, hkv, group, chunk, d).transpose(0, 1, 3, 2, 4)
        qp = qp.reshape(b, hkv, chunk * group, d)
        kp, vp, out = kern(
            block_tables, start_lens, chunk_lens, qp, k_new, v_new,
            k_pages, v_pages,
        )
        out = out.reshape(b, hkv, chunk, group, d).transpose(0, 1, 3, 2, 4)
        return out.reshape(b, hq, chunk, d), kp, vp

    # ---- XLA path: masked scatter + gather through the table -------------
    pos = start_lens[:, None].astype(jnp.int32) + jnp.arange(chunk, dtype=jnp.int32)
    logical = jnp.clip(pos // page_size, 0, max_pages - 1)
    phys = jnp.take_along_axis(block_tables, logical, axis=1)  # (B, C)
    valid = jnp.arange(chunk)[None, :] < chunk_lens[:, None]
    phys = jnp.where(valid, phys, 0)  # dead tail -> reserved garbage page
    off = pos % page_size
    k_pages, v_pages = jnp.asarray(k_pages), jnp.asarray(v_pages)
    pdt = k_pages.dtype
    kp = k_pages.at[:, phys, off].set(
        jnp.asarray(k_new).transpose(1, 0, 2, 3).astype(pdt)
    )
    vp = v_pages.at[:, phys, off].set(
        jnp.asarray(v_new).transpose(1, 0, 2, 3).astype(pdt)
    )

    def gathered(pages):
        g = pages[:, block_tables]  # (Hkv, B, max_pages, page_size, D)
        return jnp.moveaxis(g, 0, 1).reshape(b, hkv, -1, d)

    s_total = max_pages * page_size
    si = jnp.arange(s_total, dtype=jnp.int32)
    ctx_pos = jnp.where(si[None, :] < start_lens[:, None], si[None, :], -1)
    out = ref.prefill_attention(
        q, k_new, v_new, gathered(k_pages), gathered(v_pages), ctx_pos, pos,
        chunk_lens, sm_scale=sm_scale, window=window,
        logit_soft_cap=logit_soft_cap,
    )
    return out, kp, vp


def paged_attention_quant(q, k_pages, v_pages, k_scales, v_scales,
                          block_tables, seq_lens, *, fmt: str = "int8",
                          sm_scale=None, window: Optional[int] = None,
                          logit_soft_cap=None, backend: Optional[str] = None,
                          num_stages: int = 2):
    """Quantized paged decode: packed int8 K/V pools + per-token scale
    columns (see kernels/paged_attention.py).  The Pallas path dequantizes
    page-at-a-time inside the kernel (DequantStage); the XLA path is
    ref.paged_attention_quant (dequantize pools, then the fp oracle)."""
    be = _resolve(backend)
    if be == "xla" or logit_soft_cap is not None:
        return ref.paged_attention_quant(
            q, k_pages, v_pages, k_scales, v_scales, block_tables, seq_lens,
            fmt=fmt, sm_scale=sm_scale, window=window,
            logit_soft_cap=logit_soft_cap,
        )
    b, hq, d = q.shape
    hkv, num_pages, page_size, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    key = ("paged_q", fmt, b, hq, hkv, num_pages, page_size, max_pages, d,
           window, str(q.dtype), num_stages, sm_scale)
    kern = _cached(
        key,
        lambda: paged_attention_quant_program(
            b, hq, hkv, d, page_size, max_pages, num_pages, fmt, window,
            str(q.dtype), "float32", num_stages, sm_scale,
        ),
    )
    return kern(block_tables, seq_lens, q, k_pages, v_pages, k_scales, v_scales)


def prefill_attention_quant(q, k_new, v_new, k_pages, v_pages, k_scales,
                            v_scales, block_tables, start_lens, chunk_lens, *,
                            fmt: str = "int8", sm_scale=None,
                            window: Optional[int] = None, logit_soft_cap=None,
                            backend: Optional[str] = None, num_stages: int = 2):
    """Quantized chunked prefill: quantizes the chunk's fp K/V per token
    here (the write-time quantization point), then either the tile kernel
    (packed chunk in, packed page + scale writes from inside the kernel) or
    the XLA masked scatter + oracle.  Both paths attend the *dequantized
    roundtrip* of the chunk — what every later decode step will read back —
    so prefill and decode see one consistent cache.

    Returns ``(out, k_pages', v_pages', k_scales', v_scales')``.
    """
    be = _resolve(backend)
    b, hq, chunk, d = q.shape
    hkv, num_pages, page_size, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    kq, ks_new = ref.quantize_rows(k_new, fmt)
    vq, vs_new = ref.quantize_rows(v_new, fmt)
    if be != "xla" and logit_soft_cap is None and chunk % page_size == 0 \
            and chunk // page_size <= max_pages:
        group = hq // hkv
        key = ("prefill_q", fmt, b, hq, hkv, num_pages, page_size, max_pages,
               chunk, d, window, str(q.dtype), num_stages, sm_scale)
        kern = _cached(
            key,
            lambda: prefill_attention_quant_program(
                b, hq, hkv, d, chunk, page_size, max_pages, num_pages, fmt,
                window, str(q.dtype), "float32", num_stages, sm_scale,
            ),
        )
        # pack queries chunk-major with their GQA group: row = i*group + g
        qp = q.reshape(b, hkv, group, chunk, d).transpose(0, 1, 3, 2, 4)
        qp = qp.reshape(b, hkv, chunk * group, d)
        kp, vp, ksp, vsp, out = kern(
            block_tables, start_lens, chunk_lens, qp, kq, vq, ks_new, vs_new,
            k_pages, v_pages, k_scales, v_scales,
        )
        out = out.reshape(b, hkv, chunk, group, d).transpose(0, 1, 3, 2, 4)
        return out.reshape(b, hq, chunk, d), kp, vp, ksp, vsp

    # ---- XLA path: masked scatter of packed bytes + scales, then the
    # oracle over the dequantized gather -----------------------------------
    pos = start_lens[:, None].astype(jnp.int32) + jnp.arange(chunk, dtype=jnp.int32)
    logical = jnp.clip(pos // page_size, 0, max_pages - 1)
    phys = jnp.take_along_axis(block_tables, logical, axis=1)  # (B, C)
    valid = jnp.arange(chunk)[None, :] < chunk_lens[:, None]
    phys = jnp.where(valid, phys, 0)  # dead tail -> reserved garbage page
    off = pos % page_size
    k_pages, v_pages = jnp.asarray(k_pages), jnp.asarray(v_pages)
    k_scales, v_scales = jnp.asarray(k_scales), jnp.asarray(v_scales)
    kp = k_pages.at[:, phys, off].set(kq.transpose(1, 0, 2, 3))
    vp = v_pages.at[:, phys, off].set(vq.transpose(1, 0, 2, 3))
    sdt = k_scales.dtype
    ksp = k_scales.at[:, phys, off].set(ks_new.transpose(1, 0, 2, 3).astype(sdt))
    vsp = v_scales.at[:, phys, off].set(vs_new.transpose(1, 0, 2, 3).astype(sdt))

    def gathered(pages, scales):
        g = ref.dequantize_rows(pages, scales, fmt).astype(q.dtype)
        g = g[:, block_tables]  # (Hkv, B, max_pages, page_size, D)
        return jnp.moveaxis(g, 0, 1).reshape(b, hkv, -1, d)

    k_new_dq = ref.dequantize_rows(kq, ks_new, fmt).astype(q.dtype)
    v_new_dq = ref.dequantize_rows(vq, vs_new, fmt).astype(q.dtype)
    s_total = max_pages * page_size
    si = jnp.arange(s_total, dtype=jnp.int32)
    ctx_pos = jnp.where(si[None, :] < start_lens[:, None], si[None, :], -1)
    out = ref.prefill_attention(
        q, k_new_dq, v_new_dq, gathered(kp, ksp), gathered(vp, vsp), ctx_pos,
        pos, chunk_lens, sm_scale=sm_scale, window=window,
        logit_soft_cap=logit_soft_cap,
    )
    return out, kp, vp, ksp, vsp


def mla(q, q_pe, kv, k_pe, *, sm_scale=None, backend: Optional[str] = None,
        block_n: Optional[int] = None, block_h: int = 64, num_stages: int = 2):
    be = _resolve(backend)
    if be == "xla":
        return ref.mla(q, q_pe, kv, k_pe, sm_scale=sm_scale)
    b, h, d = q.shape
    pe = q_pe.shape[-1]
    s, hkv = kv.shape[1], kv.shape[2]
    bn = block_n or _pick_block(s)
    group = h // hkv
    bh = min(block_h, group)
    key = ("mla", b, h, hkv, s, d, pe, str(q.dtype), bn, bh, num_stages,
           sm_scale)
    kern = _cached(
        key,
        lambda: mla_program(
            b, h, hkv, s, d, pe, bn, bh, str(q.dtype), "float32", num_stages, sm_scale
        ),
    )
    return kern(q, q_pe, kv, k_pe)


def mla_paged(q_lat, q_pe, ckv_pages, kpe_pages, block_tables, seq_lens, *,
              sm_scale=None, window: Optional[int] = None,
              logit_soft_cap: Optional[float] = None,
              backend: Optional[str] = None, block_h: int = 64,
              num_stages: int = 2):
    """Paged MLA decode: latent queries (B, H, R) against latent/rope page
    pools gathered through a block table (see kernels/mla.py).  The Pallas
    path is the scalar-prefetch tile kernel; the XLA path is ref.mla_paged
    (what the serving engine runs on CPU hosts).  Soft-capped models route
    to the oracle — same policy as paged_attention."""
    be = _resolve(backend)
    if be == "xla" or logit_soft_cap is not None:
        return ref.mla_paged(q_lat, q_pe, ckv_pages, kpe_pages, block_tables,
                             seq_lens, sm_scale=sm_scale, window=window,
                             logit_soft_cap=logit_soft_cap)
    b, h, r = q_lat.shape
    pe = q_pe.shape[-1]
    num_pages, page_size, _ = ckv_pages.shape
    max_pages = block_tables.shape[1]
    bh = min(block_h, h)
    while h % bh:
        bh -= 1
    key = ("mla_paged", b, h, r, pe, num_pages, page_size, max_pages,
           str(q_lat.dtype), bh, num_stages, sm_scale, window)
    kern = _cached(
        key,
        lambda: mla_paged_program(
            b, h, r, pe, page_size, max_pages, num_pages, bh,
            str(q_lat.dtype), "float32", num_stages, sm_scale, window,
        ),
    )
    return kern(block_tables, seq_lens, q_lat, q_pe, ckv_pages, kpe_pages)


def mla_prefill(q_lat, q_pe, ckv_new, kpe_new, ckv_pages, kpe_pages,
                block_tables, start_lens, chunk_lens, *, sm_scale=None,
                window: Optional[int] = None,
                logit_soft_cap: Optional[float] = None,
                backend: Optional[str] = None, num_stages: int = 2):
    """MLA chunked prefill over the latent page pools.

    ``q_lat``/``q_pe`` are the chunk's absorbed queries (B, H, C, ·);
    ``ckv_new``/``kpe_new`` (B, C, ·) the chunk's own latents;
    ``start_lens`` (B,) prior resident tokens (the chunk's write offset)
    and ``chunk_lens`` (B,) the live tokens within the chunk.  Returns
    ``(out, ckv_pages', kpe_pages')`` — the chunk's latents are written
    into the pool pages through the block table, dead positions landing in
    the reserved garbage page 0.  Same contract split as
    :func:`prefill_attention`: the Pallas tile kernel writes pages from
    inside the kernel and requires chunk-aligned starts; the XLA path is
    the ref.mla_prefill oracle plus an explicit masked scatter.
    """
    be = _resolve(backend)
    b, h, chunk, r = q_lat.shape
    pe = q_pe.shape[-1]
    num_pages, page_size, _ = ckv_pages.shape
    max_pages = block_tables.shape[1]
    if be != "xla" and logit_soft_cap is None and chunk % page_size == 0 \
            and chunk // page_size <= max_pages:
        key = ("mla_prefill", b, h, r, pe, num_pages, page_size, max_pages,
               chunk, str(q_lat.dtype), num_stages, sm_scale, window)
        kern = _cached(
            key,
            lambda: mla_prefill_program(
                b, h, r, pe, chunk, page_size, max_pages, num_pages,
                str(q_lat.dtype), "float32", num_stages, sm_scale, window,
            ),
        )
        # pack queries chunk-major with their head: row = i*heads + h
        qp = q_lat.transpose(0, 2, 1, 3).reshape(b, chunk * h, r)
        qpep = q_pe.transpose(0, 2, 1, 3).reshape(b, chunk * h, pe)
        ckv_p, kpe_p, out = kern(
            block_tables, start_lens, chunk_lens, qp, qpep, ckv_new, kpe_new,
            ckv_pages, kpe_pages,
        )
        out = out.reshape(b, chunk, h, r).transpose(0, 2, 1, 3)
        return out, ckv_p, kpe_p

    # ---- XLA path: masked scatter + gather through the table -------------
    pos = start_lens[:, None].astype(jnp.int32) + jnp.arange(chunk, dtype=jnp.int32)
    logical = jnp.clip(pos // page_size, 0, max_pages - 1)
    phys = jnp.take_along_axis(block_tables, logical, axis=1)  # (B, C)
    valid = jnp.arange(chunk)[None, :] < chunk_lens[:, None]
    phys = jnp.where(valid, phys, 0)  # dead tail -> reserved garbage page
    off = pos % page_size
    ckv_pages, kpe_pages = jnp.asarray(ckv_pages), jnp.asarray(kpe_pages)
    pdt = ckv_pages.dtype
    ckv_p = ckv_pages.at[phys, off].set(jnp.asarray(ckv_new).astype(pdt))
    kpe_p = kpe_pages.at[phys, off].set(jnp.asarray(kpe_new).astype(pdt))

    s_total = max_pages * page_size
    si = jnp.arange(s_total, dtype=jnp.int32)
    ctx_pos = jnp.where(si[None, :] < start_lens[:, None], si[None, :], -1)
    out = ref.mla_prefill(
        q_lat, q_pe, ckv_new, kpe_new,
        ckv_p[block_tables].reshape(b, -1, r),
        kpe_p[block_tables].reshape(b, -1, pe),
        ctx_pos, pos, chunk_lens, sm_scale=sm_scale, window=window,
        logit_soft_cap=logit_soft_cap,
    )
    return out, ckv_p, kpe_p


def mla_paged_quant(q_lat, q_pe, ckv_pages, kpe_pages, ckv_scales, kpe_scales,
                    block_tables, seq_lens, *, fmt: str = "int8",
                    sm_scale=None, window: Optional[int] = None,
                    logit_soft_cap: Optional[float] = None,
                    backend: Optional[str] = None, block_h: int = 64,
                    num_stages: int = 2):
    """Quantized paged MLA decode: packed latent + rope pools with
    per-token scale columns.  Pallas path dequantizes inline
    (DequantStage); XLA path is ref.mla_paged_quant."""
    be = _resolve(backend)
    if be == "xla" or logit_soft_cap is not None:
        return ref.mla_paged_quant(
            q_lat, q_pe, ckv_pages, kpe_pages, ckv_scales, kpe_scales,
            block_tables, seq_lens, fmt=fmt, sm_scale=sm_scale, window=window,
            logit_soft_cap=logit_soft_cap,
        )
    b, h, r = q_lat.shape
    pe = q_pe.shape[-1]
    num_pages, page_size, _ = ckv_pages.shape
    max_pages = block_tables.shape[1]
    bh = min(block_h, h)
    while h % bh:
        bh -= 1
    key = ("mla_paged_q", fmt, b, h, r, pe, num_pages, page_size, max_pages,
           str(q_lat.dtype), bh, num_stages, sm_scale, window)
    kern = _cached(
        key,
        lambda: mla_paged_quant_program(
            b, h, r, pe, page_size, max_pages, num_pages, bh, fmt,
            str(q_lat.dtype), "float32", num_stages, sm_scale, window,
        ),
    )
    return kern(block_tables, seq_lens, q_lat, q_pe, ckv_pages, kpe_pages,
                ckv_scales, kpe_scales)


def mla_prefill_quant(q_lat, q_pe, ckv_new, kpe_new, ckv_pages, kpe_pages,
                      ckv_scales, kpe_scales, block_tables, start_lens,
                      chunk_lens, *, fmt: str = "int8", sm_scale=None,
                      window: Optional[int] = None,
                      logit_soft_cap: Optional[float] = None,
                      backend: Optional[str] = None, num_stages: int = 2):
    """Quantized MLA chunked prefill: quantizes the chunk's latents/rope per
    token here (write-time quantization), attends the dequantized roundtrip
    and writes packed pages + scales.  Returns
    ``(out, ckv_pages', kpe_pages', ckv_scales', kpe_scales')``."""
    be = _resolve(backend)
    b, h, chunk, r = q_lat.shape
    pe = q_pe.shape[-1]
    num_pages, page_size, _ = ckv_pages.shape
    max_pages = block_tables.shape[1]
    cq, cs_new = ref.quantize_rows(ckv_new, fmt)
    pq, ps_new = ref.quantize_rows(kpe_new, fmt)
    if be != "xla" and logit_soft_cap is None and chunk % page_size == 0 \
            and chunk // page_size <= max_pages:
        key = ("mla_prefill_q", fmt, b, h, r, pe, num_pages, page_size,
               max_pages, chunk, str(q_lat.dtype), num_stages, sm_scale, window)
        kern = _cached(
            key,
            lambda: mla_prefill_quant_program(
                b, h, r, pe, chunk, page_size, max_pages, num_pages, fmt,
                str(q_lat.dtype), "float32", num_stages, sm_scale, window,
            ),
        )
        # pack queries chunk-major with their head: row = i*heads + h
        qp = q_lat.transpose(0, 2, 1, 3).reshape(b, chunk * h, r)
        qpep = q_pe.transpose(0, 2, 1, 3).reshape(b, chunk * h, pe)
        ckv_p, kpe_p, cs_p, ps_p, out = kern(
            block_tables, start_lens, chunk_lens, qp, qpep, cq, pq, cs_new,
            ps_new, ckv_pages, kpe_pages, ckv_scales, kpe_scales,
        )
        out = out.reshape(b, chunk, h, r).transpose(0, 2, 1, 3)
        return out, ckv_p, kpe_p, cs_p, ps_p

    # ---- XLA path: masked scatter of packed bytes + scales, then the
    # oracle over the dequantized gather -----------------------------------
    pos = start_lens[:, None].astype(jnp.int32) + jnp.arange(chunk, dtype=jnp.int32)
    logical = jnp.clip(pos // page_size, 0, max_pages - 1)
    phys = jnp.take_along_axis(block_tables, logical, axis=1)  # (B, C)
    valid = jnp.arange(chunk)[None, :] < chunk_lens[:, None]
    phys = jnp.where(valid, phys, 0)  # dead tail -> reserved garbage page
    off = pos % page_size
    ckv_pages, kpe_pages = jnp.asarray(ckv_pages), jnp.asarray(kpe_pages)
    ckv_scales, kpe_scales = jnp.asarray(ckv_scales), jnp.asarray(kpe_scales)
    ckv_p = ckv_pages.at[phys, off].set(cq)
    kpe_p = kpe_pages.at[phys, off].set(pq)
    sdt = ckv_scales.dtype
    cs_p = ckv_scales.at[phys, off].set(cs_new.astype(sdt))
    ps_p = kpe_scales.at[phys, off].set(ps_new.astype(sdt))

    ckv_new_dq = ref.dequantize_rows(cq, cs_new, fmt).astype(q_lat.dtype)
    kpe_new_dq = ref.dequantize_rows(pq, ps_new, fmt).astype(q_lat.dtype)
    s_total = max_pages * page_size
    si = jnp.arange(s_total, dtype=jnp.int32)
    ctx_pos = jnp.where(si[None, :] < start_lens[:, None], si[None, :], -1)
    out = ref.mla_prefill(
        q_lat, q_pe, ckv_new_dq, kpe_new_dq,
        ref.dequantize_rows(ckv_p, cs_p, fmt).astype(q_lat.dtype)[
            block_tables
        ].reshape(b, -1, r),
        ref.dequantize_rows(kpe_p, ps_p, fmt).astype(q_lat.dtype)[
            block_tables
        ].reshape(b, -1, pe),
        ctx_pos, pos, chunk_lens, sm_scale=sm_scale, window=window,
        logit_soft_cap=logit_soft_cap,
    )
    return out, ckv_p, kpe_p, cs_p, ps_p


# ---------------------------------------------------------------------------
# Dequantized GEMM
# ---------------------------------------------------------------------------


def dequant_matmul(a, b_packed, *, fmt: str = "int4", scales=None,
                   backend: Optional[str] = None, block_m: Optional[int] = None,
                   block_n: Optional[int] = None, block_k: Optional[int] = None,
                   num_stages: int = 2, out_dtype=None):
    """Returns A @ dequant(B)^T with B stored (N, K//pack) packed int8.

    Note: the Pallas kernel emits the transposed product Ct[N, M] (paper
    layout) — we transpose back here so both backends agree on [M, N].
    """
    out_dtype = out_dtype or a.dtype
    be = _resolve(backend)
    if be == "xla":
        group = a.shape[1] // scales.shape[1] if scales is not None else 128
        return ref.dequant_matmul(a, b_packed, fmt, scales, group, out_dtype)
    M, K = a.shape
    N = b_packed.shape[0]
    bm = block_m or _pick_block(M, (64, 32, 16, 8))
    bn = block_n or _pick_block(N, (64, 32, 16, 8))
    bk = block_k or _pick_block(K, (128, 64, 32, 16))
    with_scales = scales is not None
    if with_scales and scales.shape[1] != K // bk:
        # kernel constraint: one scale group per K block
        return ref.dequant_matmul(
            a, b_packed, fmt, scales, K // scales.shape[1], out_dtype
        )
    key = ("dq", fmt, M, N, K, str(a.dtype), bm, bn, bk, num_stages, with_scales)
    kern = _cached(
        key,
        lambda: dequant_matmul_program(
            M, N, K, fmt, str(a.dtype), str(jnp.dtype(out_dtype)), "float32",
            bm, bn, bk, num_stages, with_scales,
        ),
    )
    args = (a, b_packed) + ((scales,) if with_scales else ())
    return kern(*args).T


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------


def chunk_state(b_mat, x, da_cum, *, backend: Optional[str] = None):
    be = _resolve(backend)
    if be == "xla":
        return ref.chunk_state(b_mat, x, da_cum)
    bsz, nc, l, n = b_mat.shape
    p = x.shape[-1]
    key = ("cstate", bsz, nc, l, n, p, str(b_mat.dtype))
    kern = _cached(
        key, lambda: chunk_state_program(bsz, nc, l, n, p, str(b_mat.dtype))
    )
    return kern(b_mat, x, da_cum.astype(jnp.float32))


def chunk_scan(c_mat, b_mat, x, da_cum, prev_states, *, backend: Optional[str] = None):
    be = _resolve(backend)
    if be == "xla":
        return ref.chunk_scan(c_mat, b_mat, x, da_cum, prev_states)
    bsz, nc, l, n = c_mat.shape
    p = x.shape[-1]
    key = ("cscan", bsz, nc, l, n, p, str(x.dtype))
    kern = _cached(
        key, lambda: chunk_scan_program(bsz, nc, l, n, p, str(x.dtype))
    )
    return kern(
        c_mat, b_mat, x, da_cum.astype(jnp.float32), prev_states.astype(jnp.float32)
    )


def ssd(c_mat, b_mat, x, dt, a_log, *, chunk: int = 64, backend: Optional[str] = None):
    """Full SSD layer pass composed from the two kernels + the inter-chunk
    recurrence (tiny lax.scan at the JAX level, as in Mamba-2)."""
    be = _resolve(backend)
    if be == "xla":
        return ref.ssd(c_mat, b_mat, x, dt, a_log, chunk)
    bsz, s, n = c_mat.shape
    p = x.shape[-1]
    nc = s // chunk
    rs = lambda t: t.reshape(bsz, nc, chunk, *t.shape[2:])
    da = dt * (-jnp.exp(a_log))
    da_cum = jnp.cumsum(da.reshape(bsz, nc, chunk), axis=-1)
    states = chunk_state(rs(b_mat), rs(x), da_cum, backend=be)
    incoming = ref.state_recurrence(states, da_cum[..., -1])
    y = chunk_scan(rs(c_mat), rs(b_mat), rs(x), da_cum, incoming, backend=be)
    return y.reshape(bsz, s, p).astype(x.dtype)


def rmsnorm(x, weight, eps: float = 1e-6, *, backend: Optional[str] = None):
    return ref.rmsnorm(x, weight, eps)
