"""GEMM in the tile DSL (paper Fig. 16 almost verbatim).

The program is the *dataflow only*: tiles of A and B stream through shared
(VMEM) windows inside a pipelined reduction loop, accumulating into a
fragment; scheduling (block shapes, stages, swizzle) arrives via the factory
arguments and the autotuner.
"""

from typing import Optional

from repro.core import Schedule, TileProgram, autotune, grid_configs
from repro.core import lang as T


def matmul_program(
    M: int,
    N: int,
    K: int,
    in_dtype: str = "float32",
    out_dtype: str = "float32",
    accum_dtype: str = "float32",
    block_M: int = 128,
    block_N: int = 128,
    block_K: int = 64,
    num_stages: int = 2,
    swizzle: Optional[int] = None,
) -> TileProgram:
    if M % block_M or N % block_N or K % block_K:
        raise ValueError(
            f"matmul {M}x{N}x{K}: blocks ({block_M},{block_N},{block_K}) must divide"
        )

    @T.prim_func
    def Matmul(
        A: T.Tensor((M, K), in_dtype),
        B: T.Tensor((K, N), in_dtype),
        C: T.Tensor((M, N), out_dtype),
    ):
        with T.Kernel(T.ceildiv(N, block_N), T.ceildiv(M, block_M), threads=128) as (bx, by):
            A_shared = T.alloc_shared((block_M, block_K), in_dtype)
            B_shared = T.alloc_shared((block_K, block_N), in_dtype)
            C_local = T.alloc_fragment((block_M, block_N), accum_dtype)
            if swizzle:
                T.use_swizzle(swizzle)
            T.clear(C_local)
            for k in T.Pipelined(T.ceildiv(K, block_K), num_stages=num_stages):
                T.copy(A[by * block_M, k * block_K], A_shared)
                T.copy(B[k * block_K, bx * block_N], B_shared)
                T.gemm(A_shared, B_shared, C_local)
            T.copy(C_local, C[by * block_M, bx * block_N])

    return Matmul


# Tiny-shape configs exercised by the pallas-vs-reference parity suite
# (tests/test_pipeline.py); the swizzled case covers the flattened grid path.
PARITY_CASES = [
    ("matmul_f32", dict(M=32, N=32, K=32, block_M=16, block_N=16, block_K=16)),
    (
        "matmul_swizzled",
        dict(M=32, N=32, K=32, block_M=16, block_N=16, block_K=16, swizzle=2),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        yield name, matmul_program(**cfg)


def default_configs(M: int, N: int, K: int):
    """Candidate schedules for the cost-model autotuner."""
    bms = [b for b in (256, 128, 64, 32) if M % b == 0]
    bns = [b for b in (256, 128, 64, 32) if N % b == 0]
    bks = [b for b in (512, 256, 128, 64, 32) if K % b == 0]
    return grid_configs(
        block_M=bms or [M],
        block_N=bns or [N],
        block_K=bks or [K],
        num_stages=[2, 3],
    )


def tune_matmul(M, N, K, in_dtype="bfloat16", out_dtype="bfloat16", schedule=None):
    def build(**cfg):
        return matmul_program(M, N, K, in_dtype, out_dtype, "float32", **cfg)

    return autotune(
        build,
        [
            c
            for c in default_configs(M, N, K)
            if M % c["block_M"] == 0 and N % c["block_N"] == 0 and K % c["block_K"] == 0
        ],
        schedule=schedule,
        cache_key=("matmul", M, N, K, in_dtype),
    )
