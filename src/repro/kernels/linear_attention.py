"""Mamba-2 SSD linear attention in the tile DSL (paper Table 4, Fig. 12).

Two kernels, exactly the chunk decomposition of Mamba-2 that the paper
benchmarks (chunk_state / chunk_scan):

* ``chunk_state``: per-chunk local state  S_c = sum_l exp(dA_L - dA_l) B_l^T x_l
* ``chunk_scan``:  y_l = exp(dA_l) C_l . S_prev  +  sum_{m<=l} (C_l.B_m) exp(dA_l - dA_m) x_m

The inter-chunk recurrence (a tiny sequential scan over chunk count) runs at
the JAX level (`ref.state_recurrence`), matching Mamba-2's own structure.

Each grid cell owns one (batch, chunk) pair; all operand tiles stream
through VMEM windows (Pallas pipelines them across grid steps even without
an explicit reduction axis).
"""

from repro.core import TileProgram
from repro.core import lang as T


def chunk_state_program(
    batch: int,
    nchunks: int,
    chunk_l: int,
    dstate: int,
    headdim: int,
    dtype: str = "float32",
    accum_dtype: str = "float32",
) -> TileProgram:
    @T.prim_func
    def ChunkState(
        B: T.Tensor((batch, nchunks, chunk_l, dstate), dtype),
        X: T.Tensor((batch, nchunks, chunk_l, headdim), dtype),
        dA: T.Tensor((batch, nchunks, chunk_l), accum_dtype),
        States: T.Tensor((batch, nchunks, dstate, headdim), accum_dtype),
    ):
        with T.Kernel(nchunks, batch, threads=128) as (bc, bz):
            B_shared = T.alloc_shared((chunk_l, dstate), dtype)
            X_shared = T.alloc_shared((chunk_l, headdim), dtype)
            dA_shared = T.alloc_shared((chunk_l,), accum_dtype)
            B_scaled = T.alloc_fragment((chunk_l, dstate), accum_dtype)
            S_local = T.alloc_fragment((dstate, headdim), accum_dtype)

            T.copy(B[bz, bc, 0, 0], B_shared)
            T.copy(X[bz, bc, 0, 0], X_shared)
            T.copy(dA[bz, bc, 0], dA_shared)
            for l, n in T.Parallel(chunk_l, dstate):
                B_scaled[l, n] = B_shared[l, n] * T.exp(
                    dA_shared[chunk_l - 1] - dA_shared[l]
                )
            T.clear(S_local)
            T.gemm(B_scaled, X_shared, S_local, transpose_A=True)
            T.copy(S_local, States[bz, bc, 0, 0])

    return ChunkState


def chunk_scan_program(
    batch: int,
    nchunks: int,
    chunk_l: int,
    dstate: int,
    headdim: int,
    dtype: str = "float32",
    accum_dtype: str = "float32",
) -> TileProgram:
    @T.prim_func
    def ChunkScan(
        C: T.Tensor((batch, nchunks, chunk_l, dstate), dtype),
        B: T.Tensor((batch, nchunks, chunk_l, dstate), dtype),
        X: T.Tensor((batch, nchunks, chunk_l, headdim), dtype),
        dA: T.Tensor((batch, nchunks, chunk_l), accum_dtype),
        PrevStates: T.Tensor((batch, nchunks, dstate, headdim), accum_dtype),
        Y: T.Tensor((batch, nchunks, chunk_l, headdim), dtype),
    ):
        with T.Kernel(nchunks, batch, threads=128) as (bc, bz):
            C_shared = T.alloc_shared((chunk_l, dstate), dtype)
            B_shared = T.alloc_shared((chunk_l, dstate), dtype)
            X_shared = T.alloc_shared((chunk_l, headdim), dtype)
            dA_shared = T.alloc_shared((chunk_l,), accum_dtype)
            S_shared = T.alloc_shared((dstate, headdim), accum_dtype)
            att = T.alloc_fragment((chunk_l, chunk_l), accum_dtype)
            y_acc = T.alloc_fragment((chunk_l, headdim), accum_dtype)
            c_f32 = T.alloc_fragment((chunk_l, dstate), accum_dtype)

            T.copy(C[bz, bc, 0, 0], C_shared)
            T.copy(B[bz, bc, 0, 0], B_shared)
            T.copy(X[bz, bc, 0, 0], X_shared)
            T.copy(dA[bz, bc, 0], dA_shared)
            T.copy(PrevStates[bz, bc, 0, 0], S_shared)

            # intra-chunk decay attention: att = tril((C B^T) * exp(dA_l - dA_m))
            T.clear(att)
            T.gemm(C_shared, B_shared, att, transpose_B=True)
            for i, j in T.Parallel(chunk_l, chunk_l):
                att[i, j] = T.if_then_else(
                    i >= j,
                    att[i, j] * T.exp(dA_shared[i] - dA_shared[j]),
                    0.0,
                )
            # y = att @ X  +  exp(dA_l) * (C @ S_prev)
            T.clear(y_acc)
            T.gemm(att, X_shared, y_acc)
            T.copy(C_shared, c_f32)
            for i, j in T.Parallel(chunk_l, dstate):
                c_f32[i, j] = c_f32[i, j] * T.exp(dA_shared[i])
            T.gemm(c_f32, S_shared, y_acc)
            T.copy(y_acc, Y[bz, bc, 0, 0])

    return ChunkScan


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py).
PARITY_CASES = [
    ("chunk_state", (chunk_state_program,
                     dict(batch=1, nchunks=2, chunk_l=16, dstate=16, headdim=16))),
    ("chunk_scan", (chunk_scan_program,
                    dict(batch=1, nchunks=2, chunk_l=16, dstate=16, headdim=16))),
]


def parity_programs():
    for name, (factory, cfg) in PARITY_CASES:
        yield name, factory(**cfg)
