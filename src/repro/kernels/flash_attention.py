"""FlashAttention in the tile DSL (paper Table 3 / Fig. 12).

Online-softmax attention with the KV sequence streamed through the grid
pipeline, composed from the shared attention core (attention_core.py):
a contiguous KV source, per-head Q blocks (GQA through the head index
map — each q-head block reads its kv group), and a causal mask.  All
scheduling (pipelining of the K/V windows, layouts, vectorization) is
inferred.

TPU adaptation notes: the m/l running statistics live in fragment buffers
(VMEM scratch persisting over the `arbitrary` KV axis) instead of registers,
and there is no warp specialization — the Pallas pipeline overlaps the KV
DMA with the two GEMMs (DESIGN.md §2).
"""

import math
from typing import Optional

from repro.core import TileProgram
from repro.core import lang as T

from . import attention_core as AC


def flash_attention_program(
    batch: int,
    heads: int,
    kv_heads: int,
    seq_q: int,
    seq_kv: int,
    head_dim: int,
    causal: bool = False,
    block_M: int = 128,
    block_N: int = 128,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
) -> TileProgram:
    if seq_q % block_M or seq_kv % block_N:
        raise ValueError("sequence lengths must be divisible by block sizes")
    if heads % kv_heads:
        raise ValueError("GQA requires heads % kv_heads == 0")
    group = heads // kv_heads
    scale = (sm_scale if sm_scale is not None else 1.0 / math.sqrt(head_dim)) * 1.44269504  # log2(e)

    @T.prim_func
    def FlashAttn(
        Q: T.Tensor((batch, heads, seq_q, head_dim), dtype),
        K: T.Tensor((batch, kv_heads, seq_kv, head_dim), dtype),
        V: T.Tensor((batch, kv_heads, seq_kv, head_dim), dtype),
        Output: T.Tensor((batch, heads, seq_q, head_dim), dtype),
    ):
        with T.Kernel(T.ceildiv(seq_q, block_M), heads, batch, threads=256) as (bx, by, bz):
            Q_shared = T.alloc_shared((block_M, head_dim), dtype)
            K_shared = T.alloc_shared((block_N, head_dim), dtype)
            V_shared = T.alloc_shared((block_N, head_dim), dtype)
            acc_s = T.alloc_fragment((block_M, block_N), accum_dtype)
            ons = AC.OnlineSoftmax(block_M, head_dim, scale, accum_dtype)

            kv_head = by // group
            T.copy(Q[bz, by, bx * block_M, 0], Q_shared)

            def load_kv(k):
                T.copy(K[bz, kv_head, k * block_N, 0], K_shared)
                T.copy(V[bz, kv_head, k * block_N, 0], V_shared)
                return K_shared, V_shared

            def mask(k):
                if not causal:
                    return None
                return AC.causal(
                    lambda i: (bx * block_M + i) + (seq_kv - seq_q),
                    lambda j: k * block_N + j,
                )

            AC.attend(
                ons, acc_s, block_N, T.ceildiv(seq_kv, block_N), load_kv,
                lambda s, ks, k: AC.scores(s, Q_shared, ks), mask,
                num_stages=num_stages,
            )
            ons.finalize(Output[bz, by, bx * block_M, 0])

    return FlashAttn


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py); covers GQA (heads != kv_heads) and the causal
# masked-elementwise path.
PARITY_CASES = [
    (
        "flash_attention_gqa",
        dict(batch=1, heads=2, kv_heads=1, seq_q=16, seq_kv=32, head_dim=16,
             block_M=16, block_N=16),
    ),
    (
        "flash_attention_causal",
        dict(batch=1, heads=1, kv_heads=1, seq_q=32, seq_kv=32, head_dim=16,
             causal=True, block_M=16, block_N=16),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        yield name, flash_attention_program(**cfg)
