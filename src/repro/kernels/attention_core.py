"""Composable attention core: one tile-level online-softmax template.

Every attention kernel in this repo — flash (training/prefill), paged
decode, chunked prefill, MLA, paged MLA, MLA prefill — is the same
dataflow: stream KV tiles through the grid pipeline, score them against a
resident Q tile, and fold each tile into a numerically-stable online
softmax (running-max rescale of the output accumulator and log-sum).  The
paper's composability thesis says the variants should differ by
*composition points*, not copy-pasted loops; this module is that template.

Composition points (each a plain Python callable evaluated at trace time —
the kernels stay ordinary ``@T.prim_func`` bodies):

* **KV source** — ``load_kv(k)`` stages step ``k``'s K/V tiles into shared
  memory: a contiguous window (``K[bz, h, k * block_N, 0]``) or a
  block-table page gather (``KPages[Tables[bz, k], 0, 0]`` — the scalar-
  prefetch path, see DESIGN.md §5.1).
* **Q packing / scoring** — :func:`scores` fills the score tile from one
  or more Q·Kᵀ GEMMs: a per-head query block, a GQA group-major packing,
  or MLA's latent+rope split (two GEMMs accumulating into one tile).
* **Score mask** — a ``mask(i, j) -> bool-expr`` composed from the
  factories below: causal, ragged live-length, sliding window, or the
  two-part ctx+chunk masks of chunked prefill.

:class:`OnlineSoftmax` owns the rescaling loop itself (the part the four
kernels used to hand-roll): running max with the ``-inf`` clamp, exp2
scaling by ``log2(e)``, l/m fragment carries, and the final normalize.
"""
from repro.core import lang as T
from repro.core.layout import LANE

# Clamp the running max before differencing: fully-masked tiles leave it at
# -inf, and (-inf) - (-inf) = nan.  -2^20; exp2 underflows long before.
NEG_CLAMP = -1048576.0


class OnlineSoftmax:
    """Online-softmax accumulator state over ``rows`` query rows.

    Allocates the m/l fragment carries (VMEM scratch persisting over the
    ``arbitrary`` KV grid axis — the TPU stand-in for registers) and fills
    them; construct it in the kernel's PRE phase, feed score tiles through
    :meth:`update` inside the pipelined loop, then :meth:`finalize`.

    Variant knobs (each preserves an existing kernel's exact op sequence):

    * ``running_max`` — False refreshes the max per tile instead of
      carrying it (the paper's Fig. 18 MLA formulation).
    * ``clamp_current`` — clamp the current max as well as the previous
      one (fully-masked tiles can leave *either* at -inf).
    * ``safe_div`` — divide by ``max(l, 1e-30)`` so fully-masked rows
      (empty slots, dead chunk rows) emit zeros rather than nan.
    * ``shared_scores`` — optional shared-memory staging buffer for the
      probability tile feeding the P·V GEMM (MLA's ``S_shared``).
    """

    def __init__(self, rows, v_dim, scale, accum_dtype="float32", *,
                 running_max=True, clamp_current=True, safe_div=False,
                 shared_scores=None):
        self.rows, self.v_dim, self.scale = rows, v_dim, scale
        self.accum_dtype = accum_dtype
        self.running_max = running_max
        self.clamp_current = clamp_current
        self.safe_div = safe_div
        self.shared_scores = shared_scores
        self.acc_o = T.alloc_fragment((rows, v_dim), accum_dtype)
        self.scores_max = T.alloc_fragment((rows,), accum_dtype)
        self.scores_max_prev = T.alloc_fragment((rows,), accum_dtype)
        self.scores_scale = T.alloc_fragment((rows,), accum_dtype)
        self.scores_sum = T.alloc_fragment((rows,), accum_dtype)
        self.logsum = T.alloc_fragment((rows,), accum_dtype)
        T.fill(self.acc_o, 0.0)
        T.fill(self.logsum, 0.0)
        T.fill(self.scores_max, -T.infinity(accum_dtype))

    def _cur(self, i):
        m = self.scores_max[i]
        return T.maximum(m, NEG_CLAMP) if self.clamp_current else m

    def update(self, acc_s, cols, v_source, mask=None):
        """Fold one scored KV tile into the accumulator.

        ``acc_s`` is the (rows, cols) score tile (already Q·Kᵀ-filled, see
        :func:`scores`), ``v_source`` the tile's V (or latent) buffer for
        the P·V GEMM, ``mask`` an optional ``(i, j) -> bool-expr``
        invalidating scores before the rescale.
        """
        neg_inf = -T.infinity(self.accum_dtype)
        if mask is not None:
            for i, j in T.Parallel(self.rows, cols):
                acc_s[i, j] = T.if_then_else(mask(i, j), acc_s[i, j], neg_inf)
        T.copy(self.scores_max, self.scores_max_prev)
        if not self.running_max:
            T.fill(self.scores_max, neg_inf)
        T.reduce_max(acc_s, self.scores_max, dim=1, clear=False)
        for i in T.Parallel(self.rows):
            self.scores_scale[i] = T.exp2(
                T.maximum(self.scores_max_prev[i], NEG_CLAMP) * self.scale
                - self._cur(i) * self.scale
            )
        for i, j in T.Parallel(self.rows, cols):
            acc_s[i, j] = T.exp2(acc_s[i, j] * self.scale - self._cur(i) * self.scale)
        T.reduce_sum(acc_s, self.scores_sum, dim=1)
        probs = acc_s
        if self.shared_scores is not None:
            T.copy(acc_s, self.shared_scores)
            probs = self.shared_scores
        for i in T.Parallel(self.rows):
            self.logsum[i] = self.logsum[i] * self.scores_scale[i] + self.scores_sum[i]
        for i, j in T.Parallel(self.rows, self.v_dim):
            self.acc_o[i, j] = self.acc_o[i, j] * self.scores_scale[i]
        T.gemm(probs, v_source, self.acc_o)

    def finalize(self, out_region):
        """Normalize by the log-sum and store to ``out_region``."""
        for i, j in T.Parallel(self.rows, self.v_dim):
            den = T.maximum(self.logsum[i], 1e-30) if self.safe_div else self.logsum[i]
            self.acc_o[i, j] = self.acc_o[i, j] / den
        T.copy(self.acc_o, out_region)


# Packed KV storage: values per int8 byte, per format (the KV-cache subset
# of dequant_matmul's _PACK — nf4/int2 stay weight-only; see DESIGN.md §5.6).
KV_PACK = {"int8": 1, "int4": 2}


class DequantStage:
    """Quantized KV source: the dequant composition point for ``load_kv``.

    Stages a packed int8 tile plus its per-row scales into shared memory,
    unpacks on the VPU with the shift/mask idiom (dequant_matmul.py's
    Fig. 15/17 fast-dequant loop lifted to the KV path), applies the scales,
    and lands the compute-dtype tile in a shared buffer ready for the MXU —
    so a quantized paged kernel differs from its fp twin only by routing
    ``load_kv`` through :meth:`load` instead of a plain ``T.copy``.

    The packed bytes and scales stay resident in ``packed_shared`` /
    ``scale_shared`` after a load: the prefill kernels re-copy those slices
    straight into the page pools through :meth:`packed_rows` (write path
    stores what was read, no re-quantization).

    The local unpack staging is *lane-padded*: ``packed_shared`` is a
    BlockSpec window (its block shape must mirror the global page layout),
    but ``packed_local`` lowers to plain VMEM scratch — and a packed minor
    dim below the TPU lane width (int4 head_dim 64 packs to 32 bytes; the
    vector unit is 8x128) would hand Mosaic a misaligned scratch tile.  So
    the fragment rounds its minor dim up to a LANE multiple, the staging
    copy fills only the live ``[0:cols]`` columns, and the padding tail is
    zeroed once at allocation so the sanitizing interpreter (DESIGN.md
    §5.8) never sees an uninitialized read whatever later passes do with
    the buffer.
    """

    def __init__(self, rows, feat, fmt, dtype="float32"):
        if fmt not in KV_PACK:
            raise ValueError(f"unsupported KV quant format {fmt}")
        self.rows, self.feat, self.fmt, self.dtype = rows, feat, fmt, dtype
        self.pack = KV_PACK[fmt]
        if feat % self.pack:
            raise ValueError("feature dim must be a multiple of the pack factor")
        self.cols = feat // self.pack  # live packed columns
        padded = -(-self.cols // LANE) * LANE
        self.packed_shared = T.alloc_shared((rows, self.cols), "int8")
        self.packed_local = T.alloc_fragment((rows, padded), "int8")
        self.scale_shared = T.alloc_shared((rows, 1), dtype)
        self.deq = T.alloc_fragment((rows, feat), dtype)
        self.out = T.alloc_shared((rows, feat), dtype)
        if padded != self.cols:
            T.clear(self.packed_local)

    def packed_rows(self, r0, r1):
        """The live packed columns of rows ``[r0:r1]`` of the staged bytes —
        what the prefill write-back copies into the page pool."""
        return self.packed_shared[r0:r1, 0:self.cols]

    def load(self, packed_region, scale_region):
        """Stage one packed tile + scales and return the dequantized tile."""
        T.copy(packed_region, self.packed_shared)
        T.copy(scale_region, self.scale_shared)
        return self.dequant()

    def dequant(self):
        """Unpack + scale whatever is staged in ``packed_shared``."""
        T.copy(self.packed_shared,
               self.packed_local[0 : self.rows, 0 : self.cols])
        if self.fmt == "int4":
            for i, j in T.Parallel(self.rows, self.feat):
                v = (self.packed_local[i, j // 2] >> ((j % 2) * 4)) & 15
                v = T.if_then_else(v >= 8, v - 16, v)
                self.deq[i, j] = T.cast(v, self.dtype)
        else:  # int8: straight cast
            for i, j in T.Parallel(self.rows, self.feat):
                self.deq[i, j] = T.cast(self.packed_local[i, j], self.dtype)
        for i, j in T.Parallel(self.rows, self.feat):
            self.deq[i, j] = self.deq[i, j] * self.scale_shared[i, 0]
        T.copy(self.deq, self.out)
        return self.out


def scores(acc_s, q, k, extra=()):
    """Fill ``acc_s`` with Q·Kᵀ — the Q-packing composition point.

    ``extra`` is further ``(q_part, k_part)`` pairs accumulated into the
    same tile: MLA's rope split scores ``q·kvᵀ + q_pe·k_peᵀ`` in one call.
    """
    T.clear(acc_s)
    T.gemm(q, k, acc_s, transpose_B=True)
    for qe, ke in extra:
        T.gemm(qe, ke, acc_s, transpose_B=True)


def attend(ons, acc_s, cols, extent, load_kv, score, mask=None, num_stages=2):
    """One pipelined online-softmax pass over ``extent`` KV tiles.

    ``load_kv(k)`` stages step ``k``'s tiles and returns ``(k_src, v_src)``
    (the KV-source composition point — contiguous window or block-table
    page gather); ``score(acc_s, k_src, k)`` fills the score tile;
    ``mask(k)`` returns the step's ``(i, j)`` mask (or None).
    """
    for k in T.Pipelined(extent, num_stages=num_stages):
        k_src, v_src = load_kv(k)
        score(acc_s, k_src, k)
        ons.update(acc_s, cols, v_src, None if mask is None else mask(k))


# ---------------------------------------------------------------------------
# Mask factories (compose with &)
# ---------------------------------------------------------------------------


def causal(q_pos, k_pos):
    """Key at ``k_pos(j)`` visible to query at ``q_pos(i)`` iff not future."""
    return lambda i, j: q_pos(i) >= k_pos(j)


def ragged(length, k_pos, window=None):
    """Live keys are ``[max(0, length - window), length)`` — decode masks
    for per-slot lengths (table padding / partial pages contribute nothing)."""
    def mask(i, j):
        valid = k_pos(j) < length
        if window is not None:
            valid = valid & (k_pos(j) >= (length - window))
        return valid
    return mask


def banded(q_pos, k_pos, window):
    """Sliding window: key within ``window`` positions behind the query."""
    return lambda i, j: (q_pos(i) - k_pos(j)) < window


def both(a, b):
    """Conjunction of two masks (None = unconstrained)."""
    if a is None:
        return b
    if b is None:
        return a
    return lambda i, j: a(i, j) & b(i, j)


def _executable_lines(src: str) -> set:
    """Line numbers carrying executable tokens — comments and docstrings
    excluded, matching what ``TileProgram.source_lines`` measures for the
    (docstring-free) kernel bodies."""
    import io
    import tokenize

    skip = {tokenize.COMMENT, tokenize.STRING, tokenize.NL, tokenize.NEWLINE,
            tokenize.INDENT, tokenize.DEDENT, tokenize.ENCODING,
            tokenize.ENDMARKER}
    lines = set()
    for tok in tokenize.generate_tokens(io.StringIO(src).readline):
        if tok.type not in skip:
            lines.add(tok.start[0])
    return lines


def source_lines() -> int:
    """Executable source lines of the online-softmax template.  bench_loc
    counts the template once against the pre-refactor sum of the
    hand-rolled softmax loops.  :class:`DequantStage` is excluded — it is
    the *quantized* kernels' composition point, charged separately
    (:func:`dequant_stage_lines`) against the four quantized variants."""
    import inspect

    mod_src, mod_start = inspect.getsource(inspect.getmodule(source_lines)), 1
    lines = _executable_lines(mod_src)
    dq_src, dq_start = inspect.getsourcelines(DequantStage)
    dq_range = set(range(dq_start, dq_start + len(dq_src)))
    return len(lines - dq_range)


def dequant_stage_lines() -> int:
    """Executable source lines of :class:`DequantStage` alone — the dequant
    KV-source composition point shared by the quantized paged / prefill /
    MLA kernels (and written once instead of four unpack loops)."""
    import inspect

    src, start = inspect.getsourcelines(DequantStage)
    return len(_executable_lines("".join(src)))
