"""End-to-end training driver.

On this CPU container it runs reduced configs on a 1×1 debug mesh (the
examples use it to train a ~small model for a few hundred steps); on real
hardware the same code paths run against ``make_production_mesh``.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b \
        --steps 50 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens, make_loader
from repro.distributed import sharding as shd
from repro.distributed.fault import FaultConfig, run_with_recovery
from repro.launch import cells as C
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.models import encdec, lm
from repro.optim import AdamWConfig, init_opt_state


def named(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def build_state(cfg, mesh, key):
    mod = encdec if cfg.is_encoder_decoder else lm
    params = mod.init(cfg, key)
    opt = init_opt_state(params)
    return {"params": params, "opt": opt}


def state_specs(cfg, mesh, state):
    pspecs = shd.param_specs(state["params"], cfg, mesh)
    oz = shd.zero1_specs(state["opt"], pspecs, mesh)
    return {"params": pspecs, "opt": oz}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--mesh", default="debug", choices=["debug", "single_pod", "multi_pod"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--failure-prob", type=float, default=0.0,
                    help="per-step injected failure probability (FT demo)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh == "debug":
        mesh = make_debug_mesh(1, 1)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi_pod")

    cell = C.Cell("cli", "train", args.seq, args.batch)
    adamw = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps)
    key = jax.random.PRNGKey(args.seed)

    with mesh:
        state = build_state(cfg, mesh, key)
        specs = state_specs(cfg, mesh, state)
        state = jax.device_put(state, named(mesh, specs))
        dspecs = C.data_specs(cfg, cell, mesh)
        step_fn = C.make_train_step(cfg, mesh, cell, adamw=adamw,
                                    logits_chunk=0)
        jit_step = jax.jit(
            step_fn,
            in_shardings=(named(mesh, specs), named(mesh, dspecs)),
            out_shardings=(named(mesh, specs), None),
            donate_argnums=(0,),
        )

        data_cfg = DataConfig(batch=args.batch, seq=args.seq,
                              vocab_size=cfg.vocab_size, seed=args.seed)
        dataset = SyntheticTokens(data_cfg)

        def loader_factory(start):
            return make_loader(dataset, start)

        ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
        fault = FaultConfig(failure_prob=args.failure_prob, seed=args.seed)

        losses = []

        def logged_step(state, batch):
            nonlocal losses
            t0 = time.time()
            if cfg.is_encoder_decoder:
                batch = dict(batch)
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.frontend_seq, cfg.d_model), cfg.dtype
                )
            elif cfg.frontend != "none":
                batch = dict(batch)
                batch["prefix_embeds"] = jnp.zeros(
                    (args.batch, cfg.frontend_seq, cfg.d_model), cfg.dtype
                )
            state, metrics = jit_step(state, batch)
            losses.append(float(metrics["loss"]))
            n = len(losses)
            if n % args.log_every == 0:
                dt = time.time() - t0
                print(
                    f"step {n:5d}  loss {losses[-1]:.4f}  "
                    f"lr {float(metrics['lr']):.2e}  "
                    f"gnorm {float(metrics['grad_norm']):.3f}  {dt*1e3:.0f} ms"
                )
            return state, metrics

        result = run_with_recovery(
            logged_step, state, loader_factory, args.steps, ckpt,
            shardings=named(mesh, specs), fault=fault,
        )
        ckpt.wait()
        print(
            f"done: {result['steps']} steps, {result['restarts']} restarts, "
            f"final loss {float(result['last_metrics']['loss']):.4f}"
        )
        return result


if __name__ == "__main__":
    main()
