# launch: production meshes, cell definitions (arch x shape), dry-run driver,
# train/serve entrypoints.
