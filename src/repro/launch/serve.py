"""Serving driver: continuous-batching engine over a reduced (CPU) or full
(TPU) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b --reduced \
        --requests 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache", choices=["paged", "contiguous"], default="paged",
                    help="KV layout (paged = block pool + block tables)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size in blocks; below slots*max_pages "
                         "oversubscribes memory and exercises preemption")
    ap.add_argument("--prefill", choices=["chunked", "replay"],
                    default="chunked",
                    help="prompt ingestion: chunked fast path (token-budget "
                         "scheduler) or legacy one-token-per-tick replay")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens per chunk-wide forward pass")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-tick token budget shared by the decode batch "
                         "and prefill chunks (default slots+prefill_chunk)")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="decode ticks per host dispatch: >1 runs the "
                         "device-resident jax.lax.scan loop when every "
                         "active slot is generating (scheduler runs at "
                         "sync boundaries only)")
    ap.add_argument("--spec-decode", choices=["ngram"], default=None,
                    help="speculative decoding draft proposer: each round "
                         "drafts --draft-len tokens (ngram = self-"
                         "speculation over the slot's own history) and "
                         "verifies all of them in one chunk forward; "
                         "greedy output stays byte-identical to plain "
                         "decode, composes multiplicatively with "
                         "--sync-every")
    ap.add_argument("--draft-len", type=int, default=4,
                    help="draft tokens proposed per speculative round "
                         "(verify chunk is draft_len+1 wide)")
    ap.add_argument("--audit", action="store_true",
                    help="run the serving invariant auditor after every "
                         "tick (page conservation, refcounts, radix "
                         "reachability, slot hygiene); raises AuditError "
                         "at the tick the books diverge")
    ap.add_argument("--guards", choices=["on", "off"], default="on",
                    help="discharge the kernels' runtime obligations "
                         "(block-table range + disjoint-write checks) "
                         "before every paged dispatch; 'off' benchmarks "
                         "raw dispatch cost without the host-side checks")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="per-request deadline in engine ticks; expired "
                         "requests exit TIMED_OUT with partial output")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec serving demo lives in examples/; use an LM arch")

    params = lm.init(cfg, jax.random.PRNGKey(args.seed))
    engine = ServingEngine(
        cfg, params,
        ServeConfig(slots=args.slots, max_len=args.max_len,
                    max_new_tokens=args.max_new,
                    temperature=args.temperature, seed=args.seed,
                    cache=args.cache, page_size=args.page_size,
                    num_blocks=args.num_blocks, prefill=args.prefill,
                    prefill_chunk=args.prefill_chunk,
                    token_budget=args.token_budget,
                    sync_every=args.sync_every,
                    spec_decode=args.spec_decode, draft_len=args.draft_len,
                    audit=args.audit,
                    guards=args.guards == "on"),
    )
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=args.prompt_len).tolist()
        engine.submit(prompt, deadline_ticks=args.deadline_ticks)

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done)
    extra = ""
    if engine.pool is not None:
        extra = (
            f", {engine.cache_mode} cache: peak {engine.peak_kv_blocks()} "
            f"blocks, {engine.preemptions} preemptions"
        )
    if engine.sync_every > 1:
        extra += (
            f", {engine.decode_windows} multi-step windows "
            f"({engine.window_fallbacks} fallbacks)"
        )
    if engine.spec_proposer is not None:
        rate = engine.spec_accepted / max(engine.spec_proposed, 1)
        extra += (
            f", {engine.spec_windows} spec windows: "
            f"{engine.spec_accepted}/{engine.spec_proposed} drafts accepted "
            f"({rate:.2f})"
        )
    ttfts = [r.ttft_ticks for r in done if r.ttft_ticks is not None]
    if ttfts:
        extra += f", mean TTFT {sum(ttfts)/len(ttfts):.1f} ticks"
    if args.audit:
        extra += f", {engine.audits_run} audits clean"
    not_completed = [r for r in done if r.status != "completed"]
    if not_completed:
        extra += f", {len(not_completed)} not completed (" + ", ".join(
            f"{r.uid}:{r.status}" for r in not_completed[:4]) + ")"
    print(
        f"served {len(done)} requests, {total_tokens} tokens in {dt:.2f}s "
        f"({total_tokens/max(dt,1e-9):.1f} tok/s, {engine.steps_run} engine steps"
        f" [{engine.prefill_mode} prefill]{extra})"
    )
    for r in done[:3]:
        print(f"  req {r.uid}: prompt {r.prompt[:4]}... -> {r.output[:8]}...")
    return done


if __name__ == "__main__":
    main()
