"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.  The dry-run host forces 512 placeholder CPU
devices; the single-pod mesh uses the first 256 of them.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax."
        )
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    # more devices than needed (512 host devices, single-pod 256): slice
    dev = np.asarray(devices[:need]).reshape(shape)
    return Mesh(dev, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (tests)."""
    import jax
    from jax.sharding import Mesh

    devices = jax.devices()[: data * model]
    dev = np.asarray(devices).reshape(data, model)
    return Mesh(dev, ("data", "model"))
