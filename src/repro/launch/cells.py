"""The assigned (architecture × input-shape) grid: 10 archs × 4 shapes.

``input_specs`` produces ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — and ``make_step``
builds the function each cell lowers:

* ``train_4k``                -> train_step (loss + grads + AdamW/ZeRO-1)
* ``prefill_32k``             -> forward (inference prefill)
* ``decode_32k`` / ``long_500k`` -> serve_step (one token against a KV/state
                                   cache of the cell's seq_len)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.models import encdec, lm
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class Cell:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, Cell] = {
    "train_4k": Cell("train_4k", "train", 4096, 256),
    "prefill_32k": Cell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Cell("decode_32k", "decode", 32768, 128),
    "long_500k": Cell("long_500k", "decode", 524288, 1),
}


def supported(cfg: ModelConfig, cell: Cell) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (skip for full-attention
    archs per the assignment, recorded in DESIGN.md)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention at 524288 tokens (per spec: skip)"
    return True, ""


# ---------------------------------------------------------------------------
# shape-only state construction (jax.eval_shape — no allocation)
# ---------------------------------------------------------------------------


def params_shapes(cfg: ModelConfig):
    mod = encdec if cfg.is_encoder_decoder else lm
    return jax.eval_shape(lambda: mod.init(cfg, jax.random.PRNGKey(0)))


def train_state_shapes(cfg: ModelConfig):
    p = params_shapes(cfg)
    opt = jax.eval_shape(init_opt_state, p)
    return {"params": p, "opt": opt}


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.is_encoder_decoder:
        return jax.eval_shape(lambda: encdec.init_cache(cfg, batch, max_len))
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, cell: Cell) -> Dict[str, jax.ShapeDtypeStruct]:
    """Data inputs for the cell's step function."""
    b, s = cell.batch, cell.seq
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    out: Dict[str, Any] = {}
    if cell.kind in ("train", "prefill"):
        out["tokens"] = tok
        if cell.kind == "train":
            out["labels"] = tok
        if cfg.is_encoder_decoder:
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        elif cfg.frontend != "none":
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
    else:  # decode: one new token against a seq-long cache
        out["token"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# sharding rules for the data/cache side
# ---------------------------------------------------------------------------


def data_specs(cfg: ModelConfig, cell: Cell, mesh: Mesh):
    bspec = shd.batch_spec(mesh, cell.batch)
    out: Dict[str, P] = {}
    if cell.kind in ("train", "prefill"):
        out["tokens"] = P(*tuple(bspec), None)
        if cell.kind == "train":
            out["labels"] = P(*tuple(bspec), None)
        if cfg.is_encoder_decoder:
            out["frames"] = P(*tuple(bspec), None, None)
        elif cfg.frontend != "none":
            out["prefix_embeds"] = P(*tuple(bspec), None, None)
    else:
        out["token"] = bspec
        out["pos"] = P()
    return out


def cache_specs(cfg: ModelConfig, cache_shape_tree, mesh: Mesh, batch: int):
    """Per-leaf cache sharding: batch over dp; heads (or failing that, the
    sequence axis) over `model`; SSM heads over `model`; MLA latent rank
    over `model`."""
    tp = shd.mesh_axis_size(mesh, "model")
    bspec = shd.batch_spec(mesh, batch)
    b_ax = tuple(bspec)[0] if len(tuple(bspec)) else None

    def rule(path, leaf):
        keys = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        names = [str(k) for k in keys]
        shape = leaf.shape
        nd = len(shape)
        base = [None] * nd

        def set_from_right(offset_from_right, axis_name):
            base[nd - offset_from_right] = axis_name

        if "kv" in names or "self" in names:  # attention K/V (.., B, H, S, hd)
            set_from_right(4, b_ax)
            if shape[nd - 3] % tp == 0:
                set_from_right(3, "model")  # head-sharded
            elif shape[nd - 2] % tp == 0:
                set_from_right(2, "model")  # split-KV over sequence
        elif "mla" in names:  # (.., B, S, 1, R)
            set_from_right(4, b_ax)
            if shape[nd - 1] % tp == 0:
                set_from_right(1, "model")
        elif names[-1] == "ssm" or "ssm" in names and shape and nd >= 4:
            # (.., B, H, N, P)
            if nd >= 4:
                set_from_right(4, b_ax)
                if shape[nd - 3] % tp == 0:
                    set_from_right(3, "model")
        elif "conv" in names:  # (.., B, W, C)
            if nd >= 3:
                set_from_right(3, b_ax)
                if shape[nd - 1] % tp == 0:
                    set_from_right(1, "model")
        # guard divisibility on the batch axis
        if nd >= 1:
            for i, ax in enumerate(base):
                if ax is not None and ax != "model":
                    sizes = (
                        np.prod([shd.mesh_axis_size(mesh, a) for a in (ax if isinstance(ax, tuple) else (ax,))])
                    )
                    if shape[i] % int(sizes) != 0:
                        base[i] = None
        return P(*base)

    return jax.tree_util.tree_map_with_path(rule, cache_shape_tree)


# ---------------------------------------------------------------------------
# interior sharding hints (see models.layers.shard_hints)
# ---------------------------------------------------------------------------


def make_hints(cfg: ModelConfig, mesh: Mesh, cell: Cell, opt_level: int = 0):
    """Activation constraints GSPMD can't infer on its own:

    * attention: shard heads over `model` when divisible; otherwise shard
      the q sequence axis (bounds the S^2 score tensor — flash-style
      partitioning) and keep K/V replicated on `model`.
    * MoE expert buffers: EP over `model` when E divides, else shard the
      capacity axis over the data axes (TP stays inside the expert FFN).

    ``opt_level >= 1`` adds the §Perf collective optimizations:
    * "block_out": SP-constrain attention/FFN outputs so the row-parallel
      psum lowers as reduce-scatter (1/TP the wire bytes of all-reduce);
    * "attn_in": materialize the gathered (full-sequence) attention input
      once, deduping the per-projection all-gathers.
    """
    from repro.models import layers as L

    tp = shd.mesh_axis_size(mesh, "model")
    bspec = shd.batch_spec(mesh, cell.batch)
    b_ax = tuple(bspec)[0] if len(tuple(bspec)) else None

    def div(n, ax):
        if ax is None:
            return True
        names = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([shd.mesh_axis_size(mesh, a) for a in names]))
        return n % size == 0

    def constrain_with(specf):
        def f(x):
            spec = specf(x.shape)
            if spec is None:
                return x
            return shd.constrain(x, mesh, spec)
        return f

    hooks = {}
    if cfg.attends:
        def attn_q(shape):  # (B, H, S, hd)
            b, h, s, _ = shape
            if div(h, "model") and h >= tp:
                return P(b_ax if div(b, b_ax) else None, "model", None, None)
            if div(s, "model"):
                return P(b_ax if div(b, b_ax) else None, None, "model", None)
            return None

        def attn_kv(shape):
            b, h, s, _ = shape
            if div(h, "model") and h >= tp:
                return P(b_ax if div(b, b_ax) else None, "model", None, None)
            # replicated K/V on model when q is sequence-sharded
            return P(b_ax if div(b, b_ax) else None, None, None, None)

        hooks["attn_q"] = constrain_with(attn_q)
        hooks["attn_kv"] = constrain_with(attn_kv)
    if cfg.moe and cfg.moe.num_experts:
        def moe_expert(shape):  # (G, E, cap, D): groups over data, EP over model
            gdim, e = shape[0], shape[1]
            dp = shd.dp_axes(mesh)
            dp_ax = dp if len(dp) > 1 else (dp[0] if dp else None)
            g_ax = dp_ax if (dp_ax is not None and div(gdim, dp_ax)) else None
            e_ax = "model" if (div(e, "model") and e >= tp) else None
            return P(g_ax, e_ax, None, None)

        hooks["moe_expert"] = constrain_with(moe_expert)

    if opt_level >= 1 and cell.kind in ("train", "prefill"):
        res = shd.residual_spec(mesh, cell.batch, cell.seq)

        def block_out(shape):  # (B, S, D) — match the residual (SP) spec
            if len(shape) != 3:
                return None
            b, s, _ = shape
            sp = tuple(res)
            if not div(b, sp[0]) or (sp[1] == "model" and s % tp):
                return None
            return res

        def attn_in(shape):  # (B, S, D) gathered once before q/k/v
            if len(shape) != 3:
                return None
            b = shape[0]
            return P(b_ax if div(b, b_ax) else None, None, None)

        hooks["block_out"] = constrain_with(block_out)
        hooks["attn_in"] = constrain_with(attn_in)
    return hooks


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh: Mesh, cell: Cell,
                    adamw: Optional[AdamWConfig] = None,
                    logits_chunk: int = 256, unroll: int = 1,
                    opt_level: int = 0) -> Callable:
    adamw = adamw or AdamWConfig()
    res_spec = shd.residual_spec(mesh, cell.batch, cell.seq)

    def constrain(x):
        return shd.constrain(x, mesh, res_spec)

    hints = make_hints(cfg, mesh, cell, opt_level)
    zero1_pspecs = None
    if opt_level >= 1:
        # ZeRO gather optimization: pin the fp32->bf16 convert BEFORE the
        # param all-gather by constraining the casted params to the ZeRO
        # (data+model) sharding — the gather then moves bf16, half the bytes.
        pshapes_ = params_shapes(cfg)
        pspecs_ = shd.param_specs(pshapes_, cfg, mesh)
        zero1_pspecs = shd.zero1_specs(
            {"master": pshapes_, "m": pshapes_, "v": pshapes_, "step": None},
            pspecs_, mesh,
        )["master"]

    def train_step(state, batch):
        from repro.models import layers as L

        if cfg.is_encoder_decoder:
            def loss(p):
                return encdec.loss_fn(p, cfg, batch["frames"], batch["tokens"],
                                      batch["labels"], unroll=unroll,
                                      remat=True, logits_chunk=logits_chunk)
        else:
            def loss(p):
                return lm.loss_fn(
                    p, cfg, batch["tokens"], batch["labels"],
                    prefix_embeds=batch.get("prefix_embeds"),
                    remat=True,
                    residual_constraint=constrain,
                    logits_chunk=logits_chunk,
                    unroll=unroll,
                )
        with L.shard_hints(**hints):
            (l, parts), grads = jax.value_and_grad(loss, has_aux=True)(
                state["params"]
            )
        new_params, new_opt, om = adamw_update(state["params"], grads, state["opt"], adamw)
        if zero1_pspecs is not None:
            new_params = jax.tree.map(
                lambda x, s: shd.constrain(x, mesh, s), new_params, zero1_pspecs
            )
        metrics = {"loss": l, **parts, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, cell: Cell,
                      unroll: int = 1, opt_level: int = 0) -> Callable:
    res_spec = shd.residual_spec(mesh, cell.batch, cell.seq)

    def constrain(x):
        return shd.constrain(x, mesh, res_spec)

    hints = make_hints(cfg, mesh, cell, opt_level)

    def prefill_step(params, batch):
        from repro.models import layers as L

        with L.shard_hints(**hints):
            if cfg.is_encoder_decoder:
                enc = encdec.encode(params, cfg, batch["frames"], unroll)
                logits = encdec.decode_full(params, cfg, batch["tokens"], enc, unroll)
                return logits[:, -1].astype(jnp.float32)
            x, _ = lm.hidden_forward(
                params, cfg, batch["tokens"],
                prefix_embeds=batch.get("prefix_embeds"),
                residual_constraint=constrain,
                unroll=unroll,
            )
            # prefill emits only the last-position logits (next-token)
            return lm._logits_of(params, cfg, x[:, -1:])[:, 0]

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh: Mesh, cell: Cell,
                    unroll: int = 1) -> Callable:
    if cfg.is_encoder_decoder:
        def serve_step(params, cache, cross, token, pos):
            return encdec.decode_step(params, cfg, cache, token, pos, cross, unroll)
        return serve_step

    def serve_step(params, cache, token, pos):
        return lm.decode_step(params, cfg, cache, token, pos, unroll=unroll)

    return serve_step
