import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build the real step function (train / prefill / serve),
feed ShapeDtypeStruct inputs with production shardings, and run
``jax.jit(...).lower().compile()`` on the 16×16 single-pod mesh and the
2×16×16 multi-pod mesh.  Success proves the distribution config is
coherent; ``memory_analysis()`` proves per-chip fit and
``cost_analysis()`` + the partitioned HLO feed §Roofline.

Results are cached as JSON under experiments/dryrun/.

Usage:
    python -m repro.launch.dryrun                      # everything
    python -m repro.launch.dryrun --arch qwen2_1_5b --shape train_4k
    python -m repro.launch.dryrun --mesh multi_pod --force
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed import sharding as shd
from repro.launch import cells as C
from repro.launch.mesh import make_production_mesh
from repro.models import encdec, lm
from repro.roofline import collective_bytes, model_flops

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _named(mesh, tree_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def build_cell(arch: str, shape: str, mesh, mesh_name: str, unroll: bool = True):
    """Returns (jitted_fn, example_args(ShapeDtypeStructs), skip_reason).

    ``unroll=True`` unrolls the layer scan so HLO cost analysis sees every
    layer (while-loop bodies are counted once); ``unroll=False`` keeps the
    loop, which the backend buffer-assigns with per-iteration reuse — the
    faithful *memory* picture.  The dry-run compiles both.
    """
    cfg = get_config(arch)
    cfg.kernel_backend = "xla"  # dry-run traces through SPMD partitioning
    cell = C.SHAPES[shape]
    ok, reason = C.supported(cfg, cell)
    if not ok:
        return None, None, reason
    n_unroll = cfg.num_layers if unroll else 1

    pshapes = C.params_shapes(cfg)
    pspecs = shd.param_specs(pshapes, cfg, mesh)
    dspecs = C.data_specs(cfg, cell, mesh)
    dshapes = C.input_specs(cfg, cell)

    if cell.kind == "train":
        state_shapes = C.train_state_shapes(cfg)
        ospecs = {
            "master": shd.zero1_specs(state_shapes["opt"], pspecs, mesh)["master"],
            "m": shd.zero1_specs(state_shapes["opt"], pspecs, mesh)["m"],
            "v": shd.zero1_specs(state_shapes["opt"], pspecs, mesh)["v"],
            "step": P(),
        }
        state_specs = {"params": pspecs, "opt": ospecs}
        step = C.make_train_step(cfg, mesh, cell, unroll=n_unroll)
        fn = jax.jit(
            step,
            in_shardings=(_named(mesh, state_specs), _named(mesh, dspecs)),
            out_shardings=(_named(mesh, state_specs), None),
            donate_argnums=(0,),  # train state is consumed -> in-place update
        )
        args = (state_shapes, dshapes)
    elif cell.kind == "prefill":
        step = C.make_prefill_step(cfg, mesh, cell, unroll=n_unroll)
        fn = jax.jit(
            step,
            in_shardings=(_named(mesh, pspecs), _named(mesh, dspecs)),
        )
        args = (pshapes, dshapes)
    else:  # decode
        cshapes = C.cache_shapes(cfg, cell.batch, cell.seq)
        cspecs = C.cache_specs(cfg, cshapes, mesh, cell.batch)
        step = C.make_serve_step(cfg, mesh, cell, unroll=n_unroll)
        if cfg.is_encoder_decoder:
            enc_shape = jax.ShapeDtypeStruct(
                (cell.batch, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            cross_shapes = jax.eval_shape(
                lambda p, e: encdec.cross_kv(p, cfg, e), pshapes, enc_shape
            )
            bspec = shd.batch_spec(mesh, cell.batch)
            b_ax = tuple(bspec)[0] if len(tuple(bspec)) else None
            cross_specs = jax.tree.map(
                lambda _: P(None, b_ax, None, None, None), cross_shapes
            )
            fn = jax.jit(
                step,
                in_shardings=(
                    _named(mesh, pspecs),
                    _named(mesh, cspecs),
                    _named(mesh, cross_specs),
                    NamedSharding(mesh, dspecs["token"]),
                    NamedSharding(mesh, P()),
                ),
                # out sharding must match the donated input's for aliasing
                out_shardings=(None, _named(mesh, cspecs)),
                donate_argnums=(1,),  # KV cache updated in place
            )
            args = (pshapes, cshapes, cross_shapes, dshapes["token"], dshapes["pos"])
        else:
            fn = jax.jit(
                step,
                in_shardings=(
                    _named(mesh, pspecs),
                    _named(mesh, cspecs),
                    NamedSharding(mesh, dspecs["token"]),
                    NamedSharding(mesh, P()),
                ),
                # out sharding must match the donated input's for aliasing
                out_shardings=(None, _named(mesh, cspecs)),
                donate_argnums=(1,),  # KV cache updated in place
            )
            args = (pshapes, cshapes, dshapes["token"], dshapes["pos"])
    return fn, args, None


def run_cell(arch: str, shape: str, mesh_name: str, force: bool = False):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    out_path = OUT_DIR / f"{arch}__{shape}__{mesh_name}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        print(f"[cached] {arch} × {shape} × {mesh_name}: {rec['status']}")
        return rec

    multi = mesh_name == "multi_pod"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "status": "error",
    }
    t0 = time.time()
    try:
        # ---- pass 1: scan build -> memory analysis (loop buffers reused)
        fn_mem, args, skip = build_cell(arch, shape, mesh, mesh_name, unroll=False)
        if skip:
            rec.update(status="skipped", reason=skip)
            out_path.write_text(json.dumps(rec, indent=1))
            print(f"[skip]   {arch} × {shape} × {mesh_name}: {skip}")
            return rec
        with mesh:
            compiled_mem = fn_mem.lower(*args).compile()
        mem = compiled_mem.memory_analysis()
        memrec = {}
        if mem is not None:
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            ):
                memrec[k] = int(getattr(mem, k, 0) or 0)
        t_mem = time.time() - t0

        # ---- pass 2: unrolled build -> cost + collective analysis.
        # The roofline table is single-pod only (per spec); the multi-pod
        # pass proves the `pod` axis shards, so it keeps the fast scan-form
        # compile (costs from it are loop-body-once and flagged as such).
        if mesh_name == "multi_pod":
            compiled = compiled_mem
            t_lower, t_compile = 0.0, t_mem
        else:
            fn_cost, args, _ = build_cell(arch, shape, mesh, mesh_name, unroll=True)
            with mesh:
                lowered = fn_cost.lower(*args)
                t_lower = time.time() - t0 - t_mem
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_mem - t_lower
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        counts = coll.pop("_instruction_counts", {})
        cfg = get_config(arch)
        cell = C.SHAPES[shape]
        rec.update(
            status="ok",
            mem_pass_s=round(t_mem, 1),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(cost.get("flops", 0.0)),
            bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            collective_bytes={k: int(v) for k, v in coll.items()},
            collective_counts=counts,
            memory=memrec,
            model_flops=model_flops(cfg, cell),
            hlo_bytes=len(hlo),
            cost_pass="scan(loop-once)" if mesh_name == "multi_pod" else "unrolled",
        )
        # per-chip residency: arguments are sharded; temp is per-device
        args_b = memrec.get("argument_size_in_bytes", 0)
        temp_b = memrec.get("temp_size_in_bytes", 0)
        out_b = memrec.get("output_size_in_bytes", 0)
        alias_b = memrec.get("alias_size_in_bytes", 0)
        rec["per_chip_bytes"] = args_b + temp_b + out_b - alias_b
        rec["fits_16gib"] = rec["per_chip_bytes"] <= 16 * 1024**3
        print(
            f"[ok]     {arch} × {shape} × {mesh_name}: "
            f"lower {t_lower:.0f}s compile {t_compile:.0f}s, "
            f"{rec['per_chip_bytes']/2**30:.2f} GiB/chip, "
            f"flops/dev {rec['flops']:.3g}"
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[FAIL]   {arch} × {shape} × {mesh_name}: {e}")
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def pipeline_smoke():
    """Numeric check of the GPipe wrapper on a real 4-stage device mesh:
    pipelined layers must equal the sequential stack."""
    from jax.sharding import Mesh

    from repro.distributed.pipeline import bubble_fraction, pipeline_forward

    devices = np.asarray(jax.devices()[:4]).reshape(4)
    mesh = Mesh(devices, ("stage",))
    L, M, B, D = 8, 6, 4, 16
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D)) * 0.1

    def block(p, x):
        return jnp.tanh(x @ p)

    x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))
    with mesh:
        out = pipeline_forward(w, x, block, mesh)
    ref = x
    for i in range(L):
        ref = jnp.tanh(ref @ w[i])
    err = float(jnp.max(jnp.abs(out - ref)))
    ok = err < 1e-5
    print(
        f"[pipeline] 4 stages x {L} layers, {M} microbatches: max err {err:.2e} "
        f"({'ok' if ok else 'FAIL'}), bubble={bubble_fraction(M, 4):.0%}"
    )
    rec = {"status": "ok" if ok else "error", "max_err": err,
           "bubble_fraction": bubble_fraction(M, 4)}
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / "pipeline_smoke.json").write_text(json.dumps(rec))
    if not ok:
        raise SystemExit(1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, choices=[None, *C.SHAPES])
    ap.add_argument("--mesh", default=None, choices=[None, "single_pod", "multi_pod"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--pipeline-smoke", action="store_true",
                    help="run the GPipe shard_map numeric check and exit")
    args = ap.parse_args()

    if args.pipeline_smoke:
        pipeline_smoke()
        return

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(C.SHAPES)
    meshes = [args.mesh] if args.mesh else ["single_pod", "multi_pod"]

    results = []
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                results.append(run_cell(arch, shape, mesh_name, force=args.force))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        for r in results:
            if r["status"] == "error":
                print(f"  FAIL {r['arch']} × {r['shape']} × {r['mesh']}: {r.get('error')}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
