from .adamw import AdamWConfig, adamw_update, init_opt_state, lr_schedule

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "lr_schedule"]
