"""AdamW with fp32 master weights (the ZeRO-1 state layout).

Model params stay in the compute dtype (bf16); the optimizer state holds
fp32 master copies plus Adam moments, all sharded over the data(+pod) axes
by ``distributed.sharding.zero1_specs`` — each data-parallel rank owns a
slice of the optimizer state, exactly ZeRO stage 1.

Gradient "compression" falls out of the dtype split: gradients are computed
(and therefore all-reduced on the ICI) in bf16, while the update itself
accumulates into the fp32 masters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cosine = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cosine)


def init_opt_state(params) -> Dict[str, Any]:
    # copy=True: a float32 param would otherwise alias its master (same
    # buffer), and donating the train state would donate it twice.
    f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params,
    grads,
    opt_state: Dict[str, Any],
    cfg: AdamWConfig,
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        new_master = master - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        )
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_w = treedef.flatten_up_to(opt_state["master"])
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    new_master = jax.tree.unflatten(treedef, new_w)
    params_dtypes = jax.tree.map(lambda p: p.dtype, params)
    new_params = jax.tree.map(lambda w, dt: w.astype(dt), new_master, params_dtypes)
    new_state = {
        "master": new_master,
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
        "step": step,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
